"""The reference ``StoreBackend``: one SQLite-WAL database (paper §III-C3).

One SQLite database holds *all* sample information for *all* Discovery
Spaces, in one generic schema that mirrors the mathematical structure of a
Discovery Space:

* ``configurations`` — elements of Ω, keyed by content hash (identity is the
  configuration's value assignment, NOT which study created it — this is what
  lets two studies reconcile to the same row, Fig. 4).
* ``property_values`` — measured/predicted values with experiment provenance.
* ``spaces`` — registered Discovery Space definitions.
* ``operations`` — named operations (optimizer runs etc.) on a space.
* ``records`` — the time-resolved sampling record: one row per sample event
  per space, with a per-operation sequence number, an action tag
  (``measured`` / ``reused`` / ``predicted`` / ``failed``) and a timestamp.
* ``value_claims`` / ``work_items`` — the lease-based coordination tables.

WAL mode makes the store safe for concurrent access by multiple processes —
the "distributed shared sample store" of paper §III-D (the paper used a SQL
database; so do we).  For many clients over a network, wrap this class in
the served backend instead (``python -m repro.core.store.server`` +
:class:`~repro.core.store.client.ClientStore`): one server process owns the
file and arbitrates every claim, so clients need no shared filesystem.

Concurrent writers
------------------

The store is written to from worker threads (``DiscoverySpace.sample_batch``)
and from independent worker processes sharing one database file.  Two
invariants make that safe:

* every statement runs — and its result rows are fully fetched — while
  holding the connection (a per-thread connection for file-backed stores, a
  single lock-guarded connection for ``:memory:``), so cursors never escape
  to racing threads;
* per-operation sequence numbers are allocated *inside* the write
  transaction, which executes atomically under SQLite's single-writer lock:
  concurrent appenders get gapless, non-duplicated ``seq`` values with no
  read-modify-write window.  The batch path allocates the base ``seq`` once
  per transaction and bulk-inserts with ``executemany`` — one MAX scan and
  one WAL commit per batch instead of per row, which is where the batched
  append throughput comes from (see ``benchmarks/store_bench.py``).

Those invariants also make the record *incrementally readable*:
:meth:`SampleStore.records_since` pages a space's record by the store-global
``rowid`` watermark (indexed, O(new rows) per call), which is what lets N
cooperating optimizers — in one process or many — fold each other's
sampling events into their own histories without ever re-reading the full
record (the campaign layer's foreign-tell sync, paper §V).

Leases and priorities
---------------------

Both coordination tables are lease-based: a measurement claim
(``value_claims``) and a running work item (``work_items``) carry a
``lease_expires_at`` timestamp that the owner refreshes periodically via
:meth:`SampleStore.renew_lease` (a heartbeat).  Liveness is therefore
decoupled from experiment duration: ``claim_timeout_s`` can be minutes for a
long cloud measurement while a *silently dead* owner — whose heartbeats
stopped — is reaped within seconds by :meth:`sweep_stale_claims` /
:meth:`requeue_stale_work`.  Both sweeps are index-driven (``vc_lease`` /
``wi_lease``), so reaping stays O(stale rows) at millions of rows instead
of a full-table scan per sweep.

``work_items`` rows also carry a ``priority`` (the optimizer's acquisition
score): :meth:`claim_work_batch` pops best-first — highest priority, then
FIFO within ties — and claims up to N items per store round-trip so remote
workers amortize slow-link latency (ExpoCloud/Lynceus-style scheduling).

All timestamps come from an injectable :class:`~repro.core.clock.Clock`, so
every reap/renew/requeue behavior is deterministically testable.
"""

from __future__ import annotations

import json
import os
import sqlite3
import threading
from contextlib import contextmanager
from typing import Iterable, Mapping, Optional, Sequence

from ..clock import Clock, SYSTEM_CLOCK
from ..entities import Configuration, PropertyValue, canonical_json
from .base import (DEFAULT_LEASE_S, RecordEntry, StoreBackend,
                   config_from_pairs)

__all__ = ["SampleStore"]

_SCHEMA = """
CREATE TABLE IF NOT EXISTS configurations (
    digest     TEXT PRIMARY KEY,
    config     TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS property_values (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    config_digest TEXT NOT NULL,
    property      TEXT NOT NULL,
    value         REAL NOT NULL,
    experiment_id TEXT NOT NULL,
    predicted     INTEGER NOT NULL DEFAULT 0,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS pv_config ON property_values(config_digest, experiment_id);
CREATE TABLE IF NOT EXISTS spaces (
    space_id   TEXT PRIMARY KEY,
    space_json TEXT NOT NULL,
    actions    TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS operations (
    operation_id TEXT PRIMARY KEY,
    space_id     TEXT NOT NULL,
    kind         TEXT NOT NULL,
    meta         TEXT NOT NULL DEFAULT '{}',
    created_at   REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS records (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    space_id      TEXT NOT NULL,
    operation_id  TEXT NOT NULL,
    seq           INTEGER NOT NULL,
    config_digest TEXT NOT NULL,
    action        TEXT NOT NULL,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS rec_space ON records(space_id, operation_id, seq);
CREATE INDEX IF NOT EXISTS rec_tail ON records(space_id, id);
CREATE TABLE IF NOT EXISTS value_claims (
    config_digest    TEXT NOT NULL,
    experiment_id    TEXT NOT NULL,
    owner            TEXT NOT NULL,
    created_at       REAL NOT NULL,
    lease_expires_at REAL NOT NULL DEFAULT 0,
    PRIMARY KEY (config_digest, experiment_id)
);
CREATE INDEX IF NOT EXISTS rec_digest ON records(space_id, config_digest);
CREATE TABLE IF NOT EXISTS work_items (
    item_id          TEXT PRIMARY KEY,
    space_id         TEXT NOT NULL,
    config_digest    TEXT NOT NULL,
    status           TEXT NOT NULL DEFAULT 'queued',
    owner            TEXT,
    action           TEXT,
    error            TEXT,
    priority         REAL NOT NULL DEFAULT 0,
    created_at       REAL NOT NULL,
    claimed_at       REAL,
    finished_at      REAL,
    lease_expires_at REAL NOT NULL DEFAULT 0
);
CREATE TABLE IF NOT EXISTS failures (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    config_digest TEXT NOT NULL,
    experiment_id TEXT NOT NULL,
    phase         TEXT NOT NULL,
    reason        TEXT NOT NULL,
    attempts      INTEGER NOT NULL DEFAULT 1,
    cost          REAL NOT NULL DEFAULT 0,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS fail_digest ON failures(config_digest, experiment_id);
"""

# Indexes over MIGRATED columns: must be created after _migrate() has run,
# or reopening a pre-migration database dies on "no such column" inside the
# schema script before the ALTERs get a chance.  wi_prio's (space_id,
# status) prefix also serves every query the old wi_queue index did, so
# that one is dropped rather than double-maintained on the queue hot path.
#
# The sweep/claim-GC scans are covered: vc_lease drives
# sweep_stale_claims's DELETE and wi_lease drives requeue_stale_work's
# UPDATE (both filter on lease expiry — without these every sweep is a
# full-table scan, paced once per lease interval by EVERY driver, which at
# 10⁶ rows dominated the rendezvous).  rec_stats covers the catalog's
# space_stats GROUP BY (space_id, action, config_digest) so catalog queries
# at depth are index-only scans.
_SCHEMA_POST_MIGRATE = """
CREATE INDEX IF NOT EXISTS wi_prio ON work_items(space_id, status, priority DESC, created_at);
CREATE INDEX IF NOT EXISTS vc_owner ON value_claims(owner);
CREATE INDEX IF NOT EXISTS vc_lease ON value_claims(lease_expires_at);
CREATE INDEX IF NOT EXISTS wi_lease ON work_items(status, lease_expires_at);
CREATE INDEX IF NOT EXISTS rec_stats ON records(space_id, action, config_digest);
DROP INDEX IF EXISTS wi_queue;
"""

# Columns added after the table first shipped: reopening a database created
# by an older build ALTERs them in (constant defaults only — a SQLite
# restriction on ADD COLUMN — so leases start expired and priorities flat).
_MIGRATIONS = {
    "value_claims": {
        "lease_expires_at": "REAL NOT NULL DEFAULT 0",
    },
    "work_items": {
        "priority": "REAL NOT NULL DEFAULT 0",
        "lease_expires_at": "REAL NOT NULL DEFAULT 0",
    },
    # Catalog columns (SpaceCatalog, paper §IV reuse discovery): the space's
    # Ω-only content digest — space_id hashes (Ω, A) so two studies with the
    # same dimensions but different action spaces get different ids, while
    # space_digest lets the catalog see they share Ω — plus entity metadata
    # (dimension names, |Ω|, observed properties) for relatedness queries
    # without parsing every space_json.
    "spaces": {
        "space_digest": "TEXT NOT NULL DEFAULT ''",
        "meta": "TEXT NOT NULL DEFAULT '{}'",
    },
}

# Allocates the next per-operation sequence number and inserts the record in
# ONE statement: atomic under SQLite's writer lock, so concurrent appenders
# (threads or processes) can never observe the same MAX(seq).
_APPEND_SQL = (
    "INSERT INTO records(space_id, operation_id, seq, config_digest, action, created_at)"
    " SELECT ?, ?, COALESCE(MAX(seq), -1) + 1, ?, ?, ?"
    " FROM records WHERE space_id=? AND operation_id=?"
)


def _like_prefix(owner: str) -> str:
    """LIKE pattern matching ``owner:<anything>`` with metacharacters in the
    (user-settable) owner escaped, so ``gpu_node_1`` can never renew or
    release ``gpu-node-1``'s claims through the ``_`` wildcard."""
    escaped = (owner.replace("\\", "\\\\")
               .replace("%", "\\%").replace("_", "\\_"))
    return escaped + ":%"


class SampleStore(StoreBackend):
    """SQLite-backed common context.  Thread-safe; multi-process safe (WAL)."""

    def __init__(self, path: str = ":memory:", clock: Optional[Clock] = None):
        self.path = path
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self._local = threading.local()
        self._memory_conn: Optional[sqlite3.Connection] = None
        self._memory_lock = threading.Lock()
        if path != ":memory:":
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
        with self._conn() as conn:
            conn.executescript(_SCHEMA)
            self._migrate(conn)
            conn.executescript(_SCHEMA_POST_MIGRATE)

    @staticmethod
    def _migrate(conn: sqlite3.Connection) -> None:
        for table, columns in _MIGRATIONS.items():
            have = {r[1] for r in conn.execute(f"PRAGMA table_info({table})")}
            for name, decl in columns.items():
                if name not in have:
                    try:
                        conn.execute(
                            f"ALTER TABLE {table} ADD COLUMN {name} {decl}")
                    except sqlite3.OperationalError as err:
                        # two processes opening a pre-migration store race
                        # the ALTER; the loser's duplicate-column error just
                        # means the winner already did the work
                        if "duplicate column" not in str(err):
                            raise

    # -- connection management ------------------------------------------------

    @contextmanager
    def _conn(self):
        """Yield a connection that is exclusively ours for the duration.

        ``:memory:`` stores share one connection across threads, serialized
        by a lock; file-backed stores get one connection per thread (SQLite
        WAL serializes writers itself).  All statement execution AND row
        fetching must happen inside this context.
        """
        if self.path == ":memory:":
            with self._memory_lock:
                if self._memory_conn is None:
                    self._memory_conn = sqlite3.connect(
                        ":memory:", check_same_thread=False, isolation_level=None
                    )
                yield self._memory_conn
            return
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=60.0, isolation_level=None)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.execute("PRAGMA busy_timeout=60000")
            self._local.conn = conn
        yield conn

    def _write(self, sql: str, params: Sequence = ()) -> int:
        """Execute a write statement; returns the last inserted rowid."""
        with self._conn() as conn:
            return conn.execute(sql, params).lastrowid

    def _rows(self, sql: str, params: Sequence = ()) -> list:
        """Execute a query and fetch all rows while holding the connection."""
        with self._conn() as conn:
            return conn.execute(sql, params).fetchall()

    @contextmanager
    def transaction(self):
        """Group writes into one SQLite transaction (``BEGIN IMMEDIATE``).

        Used by the batch write paths so N inserts hit the WAL once; the
        IMMEDIATE lock also gives multi-statement atomicity to concurrent
        writer processes.
        """
        with self._conn() as conn:
            conn.execute("BEGIN IMMEDIATE")
            try:
                yield conn
            except BaseException:
                conn.execute("ROLLBACK")
                raise
            conn.execute("COMMIT")

    # -- spaces & operations ----------------------------------------------------

    def register_space(self, space_id: str, space_json: Mapping, action_ids: Sequence[str],
                       space_digest: str = "", meta: Optional[Mapping] = None) -> None:
        """Register a Discovery Space definition (idempotent).

        ``space_digest`` is the Ω-only content hash and ``meta`` the entity
        metadata (dimension names, |Ω|, observed properties) the
        :class:`~repro.core.api.catalog.SpaceCatalog` queries; a re-register
        backfills them onto rows written by pre-catalog builds (whose
        migrated columns hold the empty defaults).
        """
        with self._conn() as conn:
            conn.execute(
                "INSERT OR IGNORE INTO spaces"
                "(space_id, space_json, actions, created_at, space_digest, meta)"
                " VALUES (?,?,?,?,?,?)",
                (space_id, canonical_json(space_json),
                 canonical_json(list(action_ids)), self.clock.time(),
                 space_digest, canonical_json(meta or {})),
            )
            if space_digest:
                conn.execute(
                    "UPDATE spaces SET space_digest=?, meta=?"
                    " WHERE space_id=? AND space_digest=''",
                    (space_digest, canonical_json(meta or {}), space_id),
                )

    def list_spaces(self) -> list:
        """Every registered space definition, oldest first — the raw rows the
        :class:`~repro.core.api.catalog.SpaceCatalog` builds entries from."""
        rows = self._rows(
            "SELECT space_id, space_json, actions, space_digest, meta,"
            " created_at FROM spaces ORDER BY created_at, space_id")
        return [
            {"space_id": r[0], "space_json": json.loads(r[1]),
             "actions": json.loads(r[2]), "space_digest": r[3],
             "meta": json.loads(r[4]), "created_at": r[5]}
            for r in rows
        ]

    def space_stats(self) -> dict:
        """Per-space sampling-record counts in one grouped scan:
        ``{space_id: {records, measured, failed, distinct}}``.  Spaces with
        an empty record are absent — the catalog treats them as 0s.  The
        ``rec_stats`` covering index makes this an index-only scan, which
        is what keeps catalog queries flat at 10⁶-record depth."""
        rows = self._rows(
            "SELECT space_id, COUNT(*), SUM(action='measured'),"
            " SUM(action='failed'), COUNT(DISTINCT config_digest)"
            " FROM records GROUP BY space_id")
        return {r[0]: {"records": int(r[1]), "measured": int(r[2] or 0),
                       "failed": int(r[3] or 0), "distinct": int(r[4])}
                for r in rows}

    def register_operation(self, operation_id: str, space_id: str, kind: str,
                           meta: Optional[Mapping] = None) -> None:
        self._write(
            "INSERT OR IGNORE INTO operations(operation_id, space_id, kind, meta, created_at)"
            " VALUES (?,?,?,?,?)",
            (operation_id, space_id, kind, canonical_json(meta or {}),
             self.clock.time()),
        )

    def operations_for(self, space_id: str) -> list:
        rows = self._rows(
            "SELECT operation_id, kind, meta, created_at FROM operations"
            " WHERE space_id=? ORDER BY created_at",
            (space_id,),
        )
        return [
            {"operation_id": r[0], "kind": r[1], "meta": json.loads(r[2]), "created_at": r[3]}
            for r in rows
        ]

    # -- configurations -----------------------------------------------------------

    def put_configuration(self, config: Configuration) -> str:
        digest = config.digest
        self._write(
            "INSERT OR IGNORE INTO configurations(digest, config, created_at) VALUES (?,?,?)",
            (digest, canonical_json(config.values), self.clock.time()),
        )
        # write-through: the decoded object we already hold IS the canonical
        # decode of what we just wrote (content-addressed, so no other value
        # can ever live under this digest)
        self._config_put(digest, config)
        return digest

    def put_configurations(self, configs: Sequence[Configuration]) -> list:
        """Intern a batch in ONE transaction (one WAL commit, one lock
        acquisition) — the ``sample_batch`` write path."""
        configs = list(configs)
        if not configs:
            return []
        now = self.clock.time()
        digests = [c.digest for c in configs]
        with self.transaction() as conn:
            conn.executemany(
                "INSERT OR IGNORE INTO configurations(digest, config, created_at)"
                " VALUES (?,?,?)",
                [(d, canonical_json(c.values), now)
                 for d, c in zip(digests, configs)],
            )
        for d, c in zip(digests, configs):
            self._config_put(d, c)
        return digests

    def get_configuration(self, digest: str) -> Optional[Configuration]:
        cached = self._config_get(digest)
        if cached is not None:
            return cached
        rows = self._rows("SELECT config FROM configurations WHERE digest=?", (digest,))
        if not rows:
            return None
        config = config_from_pairs(json.loads(rows[0][0]))
        self._config_put(digest, config)
        return config

    def get_configurations(self, digests: Sequence[str]) -> dict:
        """``{digest: Configuration}`` for every digest that exists, cache-
        aware and chunked (one IN query per 500 misses instead of a point
        query per digest)."""
        out: dict = {}
        misses = []
        for d in digests:
            cached = self._config_get(d)
            if cached is not None:
                out[d] = cached
            else:
                misses.append(d)
        for i in range(0, len(misses), 500):
            chunk = misses[i:i + 500]
            marks = ",".join("?" * len(chunk))
            for digest, config_json in self._rows(
                    f"SELECT digest, config FROM configurations"
                    f" WHERE digest IN ({marks})", chunk):
                config = config_from_pairs(json.loads(config_json))
                self._config_put(digest, config)
                out[digest] = config
        return out

    # -- property values (measurement results) --------------------------------------

    def put_values(self, config_digest: str, values: Iterable[PropertyValue]) -> None:
        """Insert one experiment's values in a single transaction, so a
        concurrent reader can never observe a half-written measurement."""
        rows = [
            (config_digest, v.name, float(v.value), v.experiment_id,
             1 if v.predicted else 0, v.timestamp)
            for v in values
        ]
        if not rows:
            return
        with self.transaction() as conn:
            conn.executemany(
                "INSERT INTO property_values"
                " (config_digest, property, value, experiment_id, predicted, created_at)"
                " VALUES (?,?,?,?,?,?)",
                rows,
            )

    def get_values(self, config_digest: str,
                   experiment_ids: Optional[Sequence[str]] = None) -> list:
        sql = ("SELECT property, value, experiment_id, predicted, created_at"
               " FROM property_values WHERE config_digest=?")
        params: list = [config_digest]
        if experiment_ids is not None:
            marks = ",".join("?" * len(experiment_ids))
            sql += f" AND experiment_id IN ({marks})"
            params.extend(experiment_ids)
        sql += " ORDER BY id"
        return [
            PropertyValue(name=r[0], value=r[1], experiment_id=r[2],
                          predicted=bool(r[3]), timestamp=r[4])
            for r in self._rows(sql, params)
        ]

    def measured_property_values(self, space_id: str, prop: str,
                                 experiment_ids: Optional[Sequence[str]] = None
                                 ) -> list:
        """``[(configuration, value), ...]``: the latest *measured* (not
        predicted) value of ``prop`` for every non-failed configuration in
        the space's sampling record, ordered by first appearance.

        Two bounded scans instead of per-digest point queries: the value
        scan ships only (digest, value) pairs — NOT the configuration JSON,
        which the old JOIN duplicated onto every property row — and the
        configurations are then decoded once per *distinct* digest through
        the interned read cache (:meth:`get_configurations`).  This is the
        SpaceCatalog's transfer-source read, which runs over a well-sampled
        space (possibly thousands of digests) once per candidate attempt.
        ``experiment_ids`` restricts provenance to the space's action space.
        """
        sql = (
            "SELECT r.config_digest, pv.value"
            " FROM (SELECT config_digest, MIN(id) AS first_id FROM records"
            "       WHERE space_id=? AND action != 'failed'"
            "       GROUP BY config_digest) r"
            " JOIN property_values pv ON pv.config_digest = r.config_digest"
            " WHERE pv.property=? AND pv.predicted=0")
        params: list = [space_id, prop]
        if experiment_ids is not None:
            marks = ",".join("?" * len(experiment_ids))
            sql += f" AND pv.experiment_id IN ({marks})"
            params.extend(experiment_ids)
        sql += " ORDER BY r.first_id, pv.id"
        latest: dict = {}
        for digest, value in self._rows(sql, params):
            # dict preserves first-appearance order; later writes for the
            # same digest overwrite the value (last measured write wins,
            # matching the read path's reconciliation)
            latest[digest] = float(value)
        configs = self.get_configurations(list(latest))
        return [(configs[digest], val) for digest, val in latest.items()
                if digest in configs]

    def frontier(self, space_id: str, properties: Sequence[str],
                 modes: Optional[Sequence[str]] = None,
                 experiment_ids: Optional[Sequence[str]] = None) -> list:
        """Reference implementation of :meth:`StoreBackend.frontier`.

        One bounded scan fetches the measured values of ALL requested
        properties together (same shape as
        :meth:`measured_property_values`, with ``pv.property IN (...)``);
        rows missing any property are dropped, the latest measured write
        wins per (configuration, property), and the dominance filter runs
        in-process over the complete tuples — the frontier is typically
        tiny next to the measured set, so shipping it pre-filtered is what
        makes this a cheap served call.
        """
        if not properties:
            raise ValueError("frontier needs at least one property")
        from ..pareto import pareto_front
        marks = ",".join("?" * len(properties))
        sql = (
            "SELECT r.config_digest, pv.property, pv.value"
            " FROM (SELECT config_digest, MIN(id) AS first_id FROM records"
            "       WHERE space_id=? AND action != 'failed'"
            "       GROUP BY config_digest) r"
            " JOIN property_values pv ON pv.config_digest = r.config_digest"
            f" WHERE pv.property IN ({marks}) AND pv.predicted=0")
        params: list = [space_id, *properties]
        if experiment_ids is not None:
            emarks = ",".join("?" * len(experiment_ids))
            sql += f" AND pv.experiment_id IN ({emarks})"
            params.extend(experiment_ids)
        sql += " ORDER BY r.first_id, pv.id"
        latest: dict = {}  # digest -> {property: value}, insertion-ordered
        for digest, prop, value in self._rows(sql, params):
            latest.setdefault(digest, {})[prop] = float(value)
        complete = [(digest, tuple(row[p] for p in properties))
                    for digest, row in latest.items()
                    if len(row) == len(properties)]
        points = [values for _, values in complete]
        keep = [complete[i] for i in pareto_front(points, modes)]
        configs = self.get_configurations([digest for digest, _ in keep])
        return [(configs[digest], values) for digest, values in keep
                if digest in configs]

    def has_values(self, config_digest: str, experiment_id: str) -> bool:
        rows = self._rows(
            "SELECT 1 FROM property_values WHERE config_digest=? AND experiment_id=? LIMIT 1",
            (config_digest, experiment_id),
        )
        return bool(rows)

    # -- measurement claims (measure-once across concurrent investigators) -----

    def claim_experiment(self, config_digest: str, experiment_id: str,
                         owner: str = "", lease_s: Optional[float] = None) -> bool:
        """Atomically claim the right to measure (configuration, experiment).

        Concurrent investigators sharing one store race through
        ``has_values -> measure``; without arbitration both deploy the same
        experiment (paying twice).  ``INSERT OR IGNORE`` on the primary key
        decides a single winner: True means *we* measure, False means someone
        else is (or already did) — wait via :meth:`wait_for_values`.

        The claim carries a lease of ``lease_s`` seconds (default
        :data:`~repro.core.store.base.DEFAULT_LEASE_S`): heartbeating owners
        take a short lease and keep it alive via :meth:`renew_lease`, so
        their death is detected in seconds; non-heartbeating owners pass
        their claim timeout, which reproduces the pre-lease reaping horizon.

        Claims persist after a successful measurement (the values themselves
        make re-claiming moot) and are :meth:`release_claim`-ed on failure so
        waiters can take over instead of stalling.
        """
        now = self.clock.time()
        expiry = now + (lease_s if lease_s is not None else DEFAULT_LEASE_S)
        with self._conn() as conn:
            cur = conn.execute(
                "INSERT OR IGNORE INTO value_claims"
                "(config_digest, experiment_id, owner, created_at, lease_expires_at)"
                " VALUES (?,?,?,?,?)",
                (config_digest, experiment_id, owner, now, expiry),
            )
            return cur.rowcount == 1

    def release_claim(self, config_digest: str, experiment_id: str) -> None:
        self._write(
            "DELETE FROM value_claims WHERE config_digest=? AND experiment_id=?",
            (config_digest, experiment_id),
        )

    def steal_claim(self, config_digest: str, experiment_id: str,
                    owner: str, older_than_s: float) -> bool:
        """Atomically take over a claim whose owner is presumed dead.

        Succeeds only if the claim's lease has EXPIRED — lease liveness is
        the one staleness signal, so a live owner heartbeating through a
        long measurement can never be robbed mid-flight, no matter how
        impatient the waiter (non-heartbeating owners carry a lease sized to
        their claim timeout, so the pre-lease stealing horizon is
        unchanged).  A single UPDATE under the writer lock: of N waiters
        racing to steal the same stale claim exactly one wins (the winner's
        refreshed lease falsifies the WHERE clause for the rest).  The
        stealer's new lease spans ``older_than_s`` (its own claim-timeout
        horizon — stealers are waiters, not heartbeaters).
        """
        now = self.clock.time()
        with self._conn() as conn:
            cur = conn.execute(
                "UPDATE value_claims SET owner=?, created_at=?, lease_expires_at=?"
                " WHERE config_digest=? AND experiment_id=?"
                " AND lease_expires_at < ?",
                (owner, now, now + older_than_s, config_digest, experiment_id,
                 now),
            )
            return cur.rowcount == 1

    def claim_exists(self, config_digest: str, experiment_id: str) -> bool:
        rows = self._rows(
            "SELECT 1 FROM value_claims WHERE config_digest=? AND experiment_id=? LIMIT 1",
            (config_digest, experiment_id),
        )
        return bool(rows)

    def sweep_stale_claims(self, *, grace_s: float = 0.0) -> int:
        """Reap claims whose lease expired (presumed-crashed owners).

        A live owner heartbeating via :meth:`renew_lease` is never reaped no
        matter how long its measurement takes; a silently dead owner's lease
        runs out within its lease horizon and the next sweep clears it.
        Lease expiry is the *only* staleness signal — there is deliberately
        no age-based fallback, which would rob live long-running owners.
        ``grace_s`` (keyword-only: the old positional parameter was an age
        threshold with the opposite meaning, and silent reinterpretation
        would be worse than a loud TypeError) reaps only claims expired at
        least that long — a strictness knob for conservative deployments.

        Complements :meth:`steal_claim`, which only fires once a waiter has
        burned its full timeout on that specific cell: the periodic sweep
        clears *all* stale claims up front, so waiters that arrive later race
        a fresh :meth:`claim_experiment` instead of a dead owner's row.
        Deleting the claim of a *successful* measurement is harmless — the
        landed values short-circuit re-claiming.  Index-driven
        (``vc_lease``): O(stale rows), not a full-table scan, which matters
        because every batch/pipelined driver paces a sweep.  Returns the
        reap count.
        """
        with self._conn() as conn:
            cur = conn.execute(
                "DELETE FROM value_claims WHERE lease_expires_at < ?",
                (self.clock.time() - max(0.0, grace_s),),
            )
            return cur.rowcount

    def renew_lease(self, owner: str, lease_s: float,
                    max_age_s: Optional[float] = None) -> int:
        """Heartbeat: extend every lease ``owner`` holds to now + ``lease_s``.

        Covers both coordination tables — the owner's measurement claims
        (exact match or ``owner:<thread>`` children) and its running work
        items.  Called periodically from a pacer thread
        (:class:`~repro.core.execution.base.LeasePacer`), this is what lets
        ``claim_timeout_s`` be minutes for long cloud measurements while a
        worker whose heartbeats stop is reaped in seconds.  Claims whose
        values already landed are NOT renewed — they are moot (the values
        short-circuit re-claiming) and skipping them keeps the heartbeat
        O(in-flight work), not O(everything the owner ever measured); the
        sweep reaps their expired leases harmlessly.

        ``max_age_s`` is the hung-owner watchdog: rows claimed more than
        that long ago are NOT renewed, so an owner whose *process* is alive
        but whose measurement thread is stuck (deadlocked experiment, hung
        I/O) stops looking live once its item exceeds the age bound and the
        normal reaping path recovers the work — workers pass their
        ``claim_timeout_s``, restoring the pre-lease guarantee that nothing
        stays claimed longer than the claim timeout without a result.
        Returns the number of leases renewed (0 is fine — an idle owner
        holds nothing).
        """
        now = self.clock.time()
        expiry = now + lease_s
        min_birth = None if max_age_s is None else now - max_age_s
        with self._conn() as conn:
            renewed = conn.execute(
                "UPDATE value_claims SET lease_expires_at=?"
                " WHERE (owner = ? OR owner LIKE ? ESCAPE '\\')"
                " AND (? IS NULL OR created_at >= ?)"
                " AND NOT EXISTS (SELECT 1 FROM property_values pv"
                "  WHERE pv.config_digest = value_claims.config_digest"
                "  AND pv.experiment_id = value_claims.experiment_id)",
                (expiry, owner, _like_prefix(owner), min_birth, min_birth),
            ).rowcount
            renewed += conn.execute(
                "UPDATE work_items SET lease_expires_at=?"
                " WHERE status='running' AND owner=?"
                " AND (? IS NULL OR claimed_at >= ?)",
                (expiry, owner, min_birth, min_birth),
            ).rowcount
            return renewed

    def release_claims_owned_by(self, owner: str) -> int:
        """Release every claim held by ``owner`` (exact match or
        ``owner:<thread>`` children) — the cleanup path when an investigator
        observes one of its worker processes die mid-measurement.  Returns
        the number of claims released."""
        with self._conn() as conn:
            cur = conn.execute(
                "DELETE FROM value_claims WHERE owner = ?"
                " OR owner LIKE ? ESCAPE '\\'",
                (owner, _like_prefix(owner)),
            )
            return cur.rowcount

    # -- the work-item queue (store-rendezvous execution, paper §III-D) ---------

    def enqueue_work(self, space_id: str, config_digest: str,
                     priority: float = 0.0) -> str:
        """Queue one (space, configuration) measurement for remote workers.

        The shared store is the *only* coordination point (§III-D): any
        worker process on any host holding this database file (or a network
        mount of it) can claim the item, run the experiments, and land values
        through the normal measurement-claim arbitration.  ``priority`` is
        the optimizer's acquisition score — workers pop best-first, so the
        most informative configurations are measured earliest (Lynceus).
        Returns the item id used to poll for completion.
        """
        import uuid
        item_id = uuid.uuid4().hex
        self._write(
            "INSERT INTO work_items"
            "(item_id, space_id, config_digest, status, priority, created_at)"
            " VALUES (?,?,?,'queued',?,?)",
            (item_id, space_id, config_digest, float(priority),
             self.clock.time()),
        )
        return item_id

    def claim_work_batch(self, owner: str, limit: int = 1,
                         space_id: Optional[str] = None,
                         lease_s: float = DEFAULT_LEASE_S) -> list:
        """Atomically pop up to ``limit`` best-priority queued work items.

        One ``BEGIN IMMEDIATE`` transaction selects and flips the rows to
        ``running`` under SQLite's single-writer lock, so racing workers
        partition the queue with no double-claims — and a worker on a slow
        link pays one store round-trip for a whole batch.  Pop order is
        highest ``priority`` first, FIFO (insertion order) within ties.
        Each claimed item starts a lease of ``lease_s`` seconds; the worker
        heartbeats it via :meth:`renew_lease` until it finishes.

        Returns ``[{item_id, space_id, config_digest, priority}, ...]``
        (empty when the queue is idle).
        """
        if limit < 1:
            return []
        now = self.clock.time()
        claims: list = []
        with self.transaction() as conn:
            rows = conn.execute(
                "SELECT item_id, space_id, config_digest, priority FROM work_items"
                " WHERE status='queued'" +
                (" AND space_id=?" if space_id is not None else "") +
                " ORDER BY priority DESC, created_at, rowid LIMIT ?",
                ((space_id, limit) if space_id is not None else (limit,)),
            ).fetchall()
            conn.executemany(
                "UPDATE work_items SET status='running', owner=?,"
                " claimed_at=?, lease_expires_at=? WHERE item_id=?",
                [(owner, now, now + lease_s, r[0]) for r in rows],
            )
            claims = [{"item_id": r[0], "space_id": r[1],
                       "config_digest": r[2], "priority": r[3]}
                      for r in rows]
        return claims

    def finish_work_batch(self, outcomes: Sequence[Sequence],
                          owner: Optional[str] = None) -> int:
        """Land ``[(item_id, action, error), ...]`` in one transaction.

        Guarded per item: only a ``running`` item is finished, and when
        ``owner`` is given it must still hold the claim — a stale worker
        whose item went silent long enough to be re-queued (and possibly
        re-claimed by the surviving fleet) cannot overwrite the
        re-execution's outcome.  One ``executemany`` per batch (sqlite3
        accumulates the total affected-row count across the statement set),
        so landing a worker's whole claim batch costs one prepared
        statement and one WAL commit.  Returns how many outcomes actually
        landed (stale ones are skipped; the caller simply moves on).
        """
        if not outcomes:
            return 0
        now = self.clock.time()
        sql = ("UPDATE work_items SET status='done', action=?, error=?,"
               " finished_at=? WHERE item_id=? AND status='running'")
        if owner is not None:
            sql += " AND owner=?"
            rows = [(action, error, now, item_id, owner)
                    for item_id, action, error in outcomes]
        else:
            rows = [(action, error, now, item_id)
                    for item_id, action, error in outcomes]
        with self.transaction() as conn:
            return conn.executemany(sql, rows).rowcount

    def fetch_work_results(self, item_ids: Sequence[str]) -> dict:
        """``{item_id: (action, error)}`` for the finished subset of ids.

        Chunked so huge in-flight batches stay under SQLite's
        bound-parameter limit (999 on older builds).
        """
        out: dict = {}
        item_ids = list(item_ids)
        for i in range(0, len(item_ids), 500):
            chunk = item_ids[i:i + 500]
            marks = ",".join("?" * len(chunk))
            rows = self._rows(
                f"SELECT item_id, action, error FROM work_items"
                f" WHERE status='done' AND item_id IN ({marks})",
                chunk,
            )
            out.update({r[0]: (r[1], r[2]) for r in rows})
        return out

    def requeue_stale_work(self, *, grace_s: float = 0.0) -> int:
        """Re-queue running items whose worker went silent (crash tolerance):
        an item whose lease expired without a result — the owner's heartbeats
        stopped — goes back to ``queued`` for the surviving fleet, keeping
        its priority.  Lease expiry is the only staleness signal (no
        age-based fallback: a heartbeating worker mid-long-measurement must
        never lose its item); ``grace_s`` re-queues only items expired at
        least that long.  Index-driven (``wi_lease``): O(stale running
        rows) per sweep.  Returns the count."""
        with self._conn() as conn:
            cur = conn.execute(
                "UPDATE work_items SET status='queued', owner=NULL,"
                " claimed_at=NULL, lease_expires_at=0"
                " WHERE status='running' AND lease_expires_at < ?",
                (self.clock.time() - max(0.0, grace_s),),
            )
            return cur.rowcount

    def pending_work(self, space_id: Optional[str] = None) -> int:
        sql = "SELECT COUNT(*) FROM work_items WHERE status IN ('queued','running')"
        params: tuple = ()
        if space_id is not None:
            sql += " AND space_id=?"
            params = (space_id,)
        return int(self._rows(sql, params)[0][0])

    def work_queue_stats(self, space_id: Optional[str] = None,
                         latency_window: int = 20) -> dict:
        """Queue-depth + latency snapshot for autoscaling policies.

        ``recent_latency_s`` is the mean claim→finish duration of the last
        ``latency_window`` finished items (None before anything finished) —
        the observed per-item cost a :class:`FleetSupervisor` feeds into its
        EWMA to size the worker fleet (ExpoCloud-style).
        """
        where = " AND space_id=?" if space_id is not None else ""
        params: tuple = (space_id,) if space_id is not None else ()
        counts = {status: 0 for status in ("queued", "running", "done")}
        for status, n in self._rows(
                "SELECT status, COUNT(*) FROM work_items WHERE 1=1" + where +
                " GROUP BY status", params):
            counts[status] = int(n)
        rows = self._rows(
            "SELECT finished_at - claimed_at FROM work_items"
            " WHERE status='done' AND finished_at IS NOT NULL"
            " AND claimed_at IS NOT NULL" + where +
            " ORDER BY finished_at DESC LIMIT ?",
            params + (latency_window,),
        )
        latency = (sum(r[0] for r in rows) / len(rows)) if rows else None
        return {"queued": counts["queued"], "running": counts["running"],
                "done": counts["done"], "recent_latency_s": latency}

    # -- the time-resolved sampling record --------------------------------------------

    def next_seq(self, space_id: str, operation_id: str) -> int:
        """The sequence number the next append would get.  Informational only:
        appenders must NOT pre-compute this — :meth:`append_record` allocates
        atomically inside its insert."""
        rows = self._rows(
            "SELECT COALESCE(MAX(seq), -1) + 1 FROM records WHERE space_id=? AND operation_id=?",
            (space_id, operation_id),
        )
        return int(rows[0][0])

    def append_record(self, space_id: str, operation_id: str, config_digest: str,
                      action: str) -> RecordEntry:
        """Append one sampling event, allocating its per-operation ``seq``
        atomically (safe under concurrent threads and processes)."""
        now = self.clock.time()
        rowid = self._write(
            _APPEND_SQL,
            (space_id, operation_id, config_digest, action, now,
             space_id, operation_id),
        )
        rows = self._rows("SELECT seq FROM records WHERE id=?", (rowid,))
        return RecordEntry(space_id, operation_id, int(rows[0][0]),
                           config_digest, action, now, rowid=int(rowid))

    def append_records(self, space_id: str, operation_id: str,
                       events: Sequence[Sequence[str]]) -> list:
        """Append ``[(config_digest, action), ...]`` in order, as one
        transaction.  Returns the created :class:`RecordEntry` list.

        This is the deterministic-ordering write path of
        ``DiscoverySpace.sample_batch``: results gathered from a worker pool
        are recorded in submission order regardless of completion order.

        Coalesced: the base ``seq`` is read ONCE under the transaction's
        write lock (which already excludes every other appender of the
        operation) and the batch bulk-inserts with ``executemany`` and
        explicit sequence numbers — one MAX scan + one prepared statement +
        one WAL commit per batch, instead of a correlated MAX subquery per
        row.  That per-row subquery was the old write hot path's cost:
        batched appends now beat the per-row path by well over the 3x
        acceptance gate (see ``benchmarks/store_bench.py``).
        """
        events = list(events)
        if not events:
            return []
        now = self.clock.time()
        with self.transaction() as conn:
            base = int(conn.execute(
                "SELECT COALESCE(MAX(seq), -1) + 1 FROM records"
                " WHERE space_id=? AND operation_id=?",
                (space_id, operation_id)).fetchone()[0])
            conn.executemany(
                "INSERT INTO records"
                "(space_id, operation_id, seq, config_digest, action, created_at)"
                " VALUES (?,?,?,?,?,?)",
                [(space_id, operation_id, base + i, digest, action, now)
                 for i, (digest, action) in enumerate(events)],
            )
            rows = conn.execute(
                "SELECT seq, id FROM records WHERE space_id=? AND operation_id=?"
                " AND seq>=? ORDER BY seq",
                (space_id, operation_id, base),
            ).fetchall()
        return [
            RecordEntry(space_id, operation_id, int(r[0]), digest, action, now,
                        rowid=int(r[1]))
            for r, (digest, action) in zip(rows, events)
        ]

    def records_for(self, space_id: str, operation_id: Optional[str] = None) -> list:
        sql = ("SELECT space_id, operation_id, seq, config_digest, action,"
               " created_at, id FROM records WHERE space_id=?")
        params: list = [space_id]
        if operation_id is not None:
            sql += " AND operation_id=?"
            params.append(operation_id)
        sql += " ORDER BY id"
        return [RecordEntry(*r) for r in self._rows(sql, params)]

    def records_since(self, space_id: str, after_rowid: int = 0,
                      limit: Optional[int] = None,
                      exclude_operation: Optional[str] = None,
                      upto_rowid: Optional[int] = None) -> list:
        """Incremental record read: every sampling event of ``space_id`` that
        committed after ``after_rowid``, in commit (= ``rowid``) order.

        This is the watermark sync the cooperative-campaign layer
        (:mod:`repro.core.campaign`) runs before every ask: a reader keeps
        the highest ``rowid`` it has folded and pays O(new rows) per sync —
        an indexed range scan (``rec_tail``) — instead of re-reading the
        whole record like :meth:`records_for`.  Correctness rests on two
        invariants: per-operation ``seq`` allocation is atomic (no gaps or
        duplicates to page over), and ``rowid`` order is commit order
        (SQLite's single-writer lock is held from id allocation to commit),
        so a record can never appear *behind* an already-observed watermark.
        Works identically for readers in other processes sharing the
        database file.  ``limit`` bounds one page; ``upto_rowid`` bounds the
        range at a snapshot tail so a pager observes a consistent prefix
        (see :meth:`~repro.core.store.base.StoreBackend.iter_records_since`,
        which drives both).  ``exclude_operation`` drops one operation's
        rows server-side — a campaign member syncing foreign history skips
        its own events in SQL instead of fetching them just to discard
        them.  NOTE: with ``limit``, excluded rows still advance the
        watermark implicitly (they are not returned), so resume from the
        last *returned* rowid as usual — correctness is unaffected because
        the member's own events are, by definition, already in its history.
        """
        sql = ("SELECT space_id, operation_id, seq, config_digest, action,"
               " created_at, id FROM records WHERE space_id=? AND id>?")
        params: list = [space_id, int(after_rowid)]
        if upto_rowid is not None:
            sql += " AND id<=?"
            params.append(int(upto_rowid))
        if exclude_operation is not None:
            sql += " AND operation_id != ?"
            params.append(exclude_operation)
        sql += " ORDER BY id"
        if limit is not None:
            sql += " LIMIT ?"
            params.append(int(limit))
        return [RecordEntry(*r) for r in self._rows(sql, params)]

    def last_record_rowid(self, space_id: str) -> int:
        """The space's current record-tail ``rowid`` (0 when empty): the
        watermark a reader starts from to observe only FUTURE events.  An
        O(1) index-tail lookup (``rec_tail``) — campaigns call this at
        construction, where reading the whole record just to find its tail
        would defeat the incremental-read design."""
        rows = self._rows(
            "SELECT COALESCE(MAX(id), 0) FROM records WHERE space_id=?",
            (space_id,))
        return int(rows[0][0])

    def has_record(self, space_id: str, config_digest: str,
                   include_failed: bool = False) -> bool:
        """Point query: is this configuration in the space's sampling record?
        Indexed (``rec_digest``), so membership checks don't rebuild the full
        sampled-digest set the way :meth:`sampled_digests` does."""
        sql = "SELECT 1 FROM records WHERE space_id=? AND config_digest=?"
        if not include_failed:
            sql += " AND action != 'failed'"
        return bool(self._rows(sql + " LIMIT 1", (space_id, config_digest)))

    def sampled_digests(self, space_id: str, include_failed: bool = False) -> list:
        """Distinct configuration digests in this space's sampling record,
        ordered by first appearance (deterministic across serial/parallel
        runs that recorded the same event sequence)."""
        sql = ("SELECT config_digest FROM records WHERE space_id=?"
               "{} GROUP BY config_digest ORDER BY MIN(id)")
        sql = sql.format("" if include_failed else " AND action != 'failed'")
        return [r[0] for r in self._rows(sql, (space_id,))]

    # -- failure provenance (actuation lifecycle) ---------------------------------------

    def record_failure(self, config_digest: str, experiment_id: str,
                       phase: str, reason: str, attempts: int = 1,
                       cost: float = 0.0) -> None:
        """Persist one failed trial's structured provenance (see the base
        interface).  Keyed on the digest like property values — the failure
        is a fact about the configuration, shared across spaces."""
        self._write(
            "INSERT INTO failures"
            "(config_digest, experiment_id, phase, reason, attempts, cost, created_at)"
            " VALUES (?,?,?,?,?,?,?)",
            (config_digest, experiment_id, phase, reason, int(attempts),
             float(cost), self.clock.time()),
        )

    def failures_for(self, config_digest: str,
                     experiment_id: Optional[str] = None) -> list:
        sql = ("SELECT config_digest, experiment_id, phase, reason, attempts,"
               " cost, created_at FROM failures WHERE config_digest=?")
        params: list = [config_digest]
        if experiment_id is not None:
            sql += " AND experiment_id=?"
            params.append(experiment_id)
        sql += " ORDER BY id"
        return [
            {"config_digest": r[0], "experiment_id": r[1], "phase": r[2],
             "reason": r[3], "attempts": int(r[4]), "cost": float(r[5]),
             "created_at": r[6]}
            for r in self._rows(sql, params)
        ]

    def failure_summary(self, space_id: str) -> dict:
        """Per-phase failure accounting over the space's failed records.

        A LEFT JOIN against the failure table backfills legacy failed
        records — rows written before structured failure provenance existed
        have no failures row, and surface under phase ``"unknown"`` with
        zero cost.  One failed record joins its digest's LATEST failure row
        (not every retry of every operation), so a digest that failed once
        contributes once per failed record.
        """
        rows = self._rows(
            "SELECT COALESCE(f.phase, 'unknown'), COUNT(*),"
            " COALESCE(SUM(f.cost), 0)"
            " FROM records r LEFT JOIN failures f ON f.id ="
            "  (SELECT MAX(f2.id) FROM failures f2"
            "   WHERE f2.config_digest = r.config_digest)"
            " WHERE r.space_id=? AND r.action='failed'"
            " GROUP BY COALESCE(f.phase, 'unknown')",
            (space_id,),
        )
        return {r[0]: {"count": int(r[1]), "cost": float(r[2] or 0.0)}
                for r in rows}

    # -- statistics --------------------------------------------------------------------

    def count_measured(self, space_id: Optional[str] = None) -> int:
        if space_id is None:
            rows = self._rows("SELECT COUNT(*) FROM records WHERE action='measured'")
        else:
            rows = self._rows(
                "SELECT COUNT(*) FROM records WHERE action='measured' AND space_id=?",
                (space_id,),
            )
        return int(rows[0][0])

    def close(self) -> None:
        if self.path == ":memory:":
            with self._memory_lock:
                if self._memory_conn is not None:
                    self._memory_conn.close()
                    self._memory_conn = None
        else:
            conn = getattr(self._local, "conn", None)
            if conn is not None:
                conn.close()
                self._local.conn = None
