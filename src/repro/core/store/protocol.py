"""Wire protocol of the served store: length-prefixed msgpack/JSON frames.

Every message — request or response — is one *frame*:

.. code-block:: text

    +----------------+-------+------------------+
    | length (4B BE) | codec |  payload bytes   |
    +----------------+-------+------------------+

``length`` counts ``codec + payload``; ``codec`` is one byte — ``b"M"`` for
msgpack, ``b"J"`` for UTF-8 JSON.  msgpack is the default (compact, fast,
already a repo dependency); JSON is the fallback so a store server remains
reachable from environments without msgpack (and trivially debuggable with
``socat``).  The server answers each request in the codec it arrived in, so
mixed-codec clients can share one server.

Payloads are positional arrays, not maps — small on the wire and
order-stable:

* request:  ``[req_id, method, args]`` where ``args`` is a list of
  positional arguments for the store method (keyword-only params travel
  positionally in the method's declared order).
* response: ``[req_id, ok, payload]`` — ``ok`` is a bool; on success
  ``payload`` is the return value, on failure it is ``[exc_type, message]``
  and the client re-raises.

``req_id`` is an arbitrary integer the client chooses; the server echoes it
back.  Responses to one connection's requests are sent in request order, so
a *pipelining* client can write N request frames back-to-back and then read
N responses — one network round-trip for a whole batch, which is what keeps
the served backend's batched paths (``put_configurations``,
``append_records``, ``finish_work_batch``) within striking distance of the
in-process store (see ``benchmarks/store_bench.py``).

Value coercion
--------------

The protocol ships plain data only.  Rich store types cross the wire as:

* :class:`~repro.core.entities.Configuration` — its value-pair list (the
  same shape its canonical JSON uses); tuples are restored client-side via
  :func:`~repro.core.store.base._thaw`.
* :class:`~repro.core.entities.PropertyValue` — a 5-tuple
  ``(name, value, experiment_id, predicted, timestamp)``.
* :class:`~repro.core.store.base.RecordEntry` — a 7-tuple in field order.
* failure provenance (``record_failure`` / ``failures_for`` /
  ``failure_summary``) — plain maps end to end: a failure row is
  ``{config_digest, experiment_id, phase, reason, attempts, cost,
  created_at}`` and a summary is ``{phase: {count, cost}}``; no dataclass
  crosses the wire, so both codecs pass them through unchanged.

Both codecs lose tuple-ness (msgpack and JSON render tuples as arrays), so
every decode path rebuilds the dataclasses explicitly — never trust
container types off the wire.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any, Optional, Tuple

try:  # msgpack is a baked-in dependency, but the JSON path keeps the
    import msgpack  # served store importable (and testable) without it
except ImportError:  # pragma: no cover - exercised via codec='J' tests
    msgpack = None

__all__ = ["send_frame", "recv_frame", "encode", "decode",
           "FrameError", "MAX_FRAME", "DEFAULT_CODEC"]

_LEN = struct.Struct(">I")

#: Upper bound on one frame's body (codec byte + payload): 64 MiB comfortably
#: holds the largest legitimate message (a 1024-entry record page is ~100 KiB)
#: while a corrupt/hostile length prefix can't make either side allocate
#: gigabytes.
MAX_FRAME = 64 * 1024 * 1024

DEFAULT_CODEC = b"M" if msgpack is not None else b"J"


class FrameError(ConnectionError):
    """A malformed frame (bad codec byte, oversized length, short read)."""


def encode(obj: Any, codec: bytes = DEFAULT_CODEC) -> bytes:
    """Serialize ``obj`` into a frame body (codec byte + payload)."""
    if codec == b"M":
        if msgpack is None:
            raise FrameError("msgpack codec requested but msgpack is unavailable")
        return b"M" + msgpack.packb(obj, use_bin_type=True)
    if codec == b"J":
        return b"J" + json.dumps(obj, separators=(",", ":")).encode("utf-8")
    raise FrameError(f"unknown codec {codec!r}")


def decode(body: bytes) -> Any:
    """Deserialize a frame body produced by :func:`encode`."""
    if not body:
        raise FrameError("empty frame body")
    codec, payload = body[:1], body[1:]
    if codec == b"M":
        if msgpack is None:
            raise FrameError("received msgpack frame but msgpack is unavailable")
        return msgpack.unpackb(payload, raw=False, strict_map_key=False)
    if codec == b"J":
        return json.loads(payload.decode("utf-8"))
    raise FrameError(f"unknown codec {codec!r}")


def send_frame(sock: socket.socket, obj: Any,
               codec: bytes = DEFAULT_CODEC) -> None:
    """Write one framed message (a single ``sendall`` — atomic enough for
    interleaving-free pipelined writes from one thread)."""
    body = encode(obj, codec)
    if len(body) > MAX_FRAME:
        raise FrameError(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise FrameError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Optional[Tuple[Any, bytes]]:
    """Read one framed message: ``(decoded, codec)``, or None on clean EOF.

    The codec is returned so a server can answer in the client's dialect.
    """
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    if length == 0 or length > MAX_FRAME:
        raise FrameError(f"invalid frame length {length}")
    body = _recv_exact(sock, length)
    if body is None:
        raise FrameError("connection closed before frame body")
    return decode(body), body[:1]
