"""The ``StoreBackend`` interface: every way a sample store can be reached.

The paper's §III-D rendezvous — one *common context* shared by every
investigator — was first built as a single SQLite file
(:class:`~repro.core.store.sqlite.SampleStore`).  That class remains the
reference implementation, but everything above the store (the Discovery
Space, the execution backends, the campaign sync, the Investigation API)
talks to this interface, so the rendezvous can also be *served*: one store
process mediating many investigations over a socket
(:class:`~repro.core.store.client.ClientStore` +
``python -m repro.core.store.server`` — the ExpoCloud controller/worker
shape), with claim arbitration happening inside the single server process.

Contract highlights every backend must honor:

* **content-addressed configurations** — ``put_configuration`` is
  idempotent; a digest, once written, never maps to different values.  This
  immutability is what lets backends cache decoded configurations without a
  cross-process invalidation protocol (see :meth:`StoreBackend._config_get`).
* **atomic per-operation ``seq``** — concurrent appenders observe gapless,
  non-duplicated sequence numbers.
* **commit-ordered ``rowid``** — :meth:`records_since` pages on a watermark
  that can never run backwards; a record is visible only after its values
  are durable.
* **single-winner claims** — of N racing ``claim_experiment`` /
  ``claim_work_batch`` callers exactly one wins each cell/item, regardless
  of which process (or host) they run in.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Iterable, Iterator, Mapping, Optional, Sequence

from ..clock import Clock, SYSTEM_CLOCK
from ..entities import Configuration, PropertyValue

__all__ = ["StoreBackend", "RecordEntry", "DEFAULT_LEASE_S"]

#: Lease horizon for claimants that did not specify one (non-heartbeating
#: owners): matches the pre-lease default claim timeout.
DEFAULT_LEASE_S = 60.0

#: Decoded-configuration cache bound, per backend instance.  Configurations
#: are content-addressed and immutable, so entries can never go stale — the
#: cap only bounds memory at catalog scale (10⁶-record stores still hold
#: far fewer *distinct* configurations than records).
CONFIG_CACHE_MAX = 65536

#: Default page bound for :meth:`StoreBackend.iter_records_since`: big
#: enough to amortize per-call overhead, small enough that a sync against a
#: deep record never materializes millions of rows in one list.
RECORD_PAGE_SIZE = 1024


@dataclass(frozen=True)
class RecordEntry:
    """One entry of a space's time-resolved sampling record.

    ``rowid`` is the store-global insertion id of the row: strictly
    increasing in commit order across *all* operations of *all* spaces.
    It is the watermark :meth:`StoreBackend.records_since` pages on — a
    reader that remembers the highest ``rowid`` it has seen can fetch
    exactly the records that landed since, in O(new rows).
    """

    space_id: str
    operation_id: str
    seq: int
    config_digest: str
    action: str
    created_at: float
    rowid: int = 0


def _thaw(v: Any) -> Any:
    """JSON/msgpack round-trips turn tuples into lists; configuration values
    are hashable tuples — restore them on every decode path."""
    if isinstance(v, (list, tuple)):
        return tuple(_thaw(x) for x in v)
    return v


def config_from_pairs(pairs: Iterable) -> Configuration:
    """Rebuild a :class:`Configuration` from its serialized value pairs."""
    return Configuration(values=tuple((k, _thaw(v)) for k, v in pairs))


class StoreBackend:
    """Abstract common-context store (paper §III-C3/§III-D).

    Subclasses provide the primitive methods; this base supplies the
    derived conveniences every backend shares — single-item claim/finish
    shims, the claim-waiting poll loop, snapshot-bounded record paging, and
    the immutable-configuration read cache.
    """

    #: Backend identity handed to out-of-process children so they can open
    #: their OWN handle: a filesystem path for SQLite, a ``tcp://`` /
    #: ``unix://`` URL for the served store (see :func:`repro.core.store.open_store`).
    path: str = ":memory:"
    clock: Clock = SYSTEM_CLOCK

    # -- primitives every backend implements --------------------------------

    def register_space(self, space_id: str, space_json: Mapping,
                       action_ids: Sequence[str], space_digest: str = "",
                       meta: Optional[Mapping] = None) -> None:
        raise NotImplementedError

    def list_spaces(self) -> list:
        raise NotImplementedError

    def space_stats(self) -> dict:
        raise NotImplementedError

    def register_operation(self, operation_id: str, space_id: str, kind: str,
                           meta: Optional[Mapping] = None) -> None:
        raise NotImplementedError

    def operations_for(self, space_id: str) -> list:
        raise NotImplementedError

    def put_configuration(self, config: Configuration) -> str:
        raise NotImplementedError

    def get_configuration(self, digest: str) -> Optional[Configuration]:
        raise NotImplementedError

    def put_values(self, config_digest: str,
                   values: Iterable[PropertyValue]) -> None:
        raise NotImplementedError

    def get_values(self, config_digest: str,
                   experiment_ids: Optional[Sequence[str]] = None) -> list:
        raise NotImplementedError

    def measured_property_values(self, space_id: str, prop: str,
                                 experiment_ids: Optional[Sequence[str]] = None
                                 ) -> list:
        raise NotImplementedError

    def frontier(self, space_id: str, properties: Sequence[str],
                 modes: Optional[Sequence[str]] = None,
                 experiment_ids: Optional[Sequence[str]] = None) -> list:
        """``[(configuration, values), ...]``: the Pareto-non-dominated
        *measured* points of a space over ``properties`` — the
        multi-objective view behind SLA-constrained investigations.

        ``values`` is a float tuple aligned with ``properties``; ``modes``
        gives each property's direction (``min``/``max``, default all-min).
        Only configurations with a measured (never predicted) value for
        EVERY requested property participate — a partial row cannot be
        compared — with the latest measured write winning per property,
        matching :meth:`measured_property_values`.  Rows come back in
        first-sampled order.  Backends must agree exactly (conformance-gated
        in ``tests/test_store_backends.py``).
        """
        raise NotImplementedError

    def has_values(self, config_digest: str, experiment_id: str) -> bool:
        raise NotImplementedError

    def claim_experiment(self, config_digest: str, experiment_id: str,
                         owner: str = "",
                         lease_s: Optional[float] = None) -> bool:
        raise NotImplementedError

    def release_claim(self, config_digest: str, experiment_id: str) -> None:
        raise NotImplementedError

    def steal_claim(self, config_digest: str, experiment_id: str,
                    owner: str, older_than_s: float) -> bool:
        raise NotImplementedError

    def claim_exists(self, config_digest: str, experiment_id: str) -> bool:
        raise NotImplementedError

    def sweep_stale_claims(self, *, grace_s: float = 0.0) -> int:
        raise NotImplementedError

    def renew_lease(self, owner: str, lease_s: float,
                    max_age_s: Optional[float] = None) -> int:
        raise NotImplementedError

    def release_claims_owned_by(self, owner: str) -> int:
        raise NotImplementedError

    def enqueue_work(self, space_id: str, config_digest: str,
                     priority: float = 0.0) -> str:
        raise NotImplementedError

    def claim_work_batch(self, owner: str, limit: int = 1,
                         space_id: Optional[str] = None,
                         lease_s: float = DEFAULT_LEASE_S) -> list:
        raise NotImplementedError

    def finish_work_batch(self, outcomes: Sequence[Sequence],
                          owner: Optional[str] = None) -> int:
        raise NotImplementedError

    def fetch_work_results(self, item_ids: Sequence[str]) -> dict:
        raise NotImplementedError

    def requeue_stale_work(self, *, grace_s: float = 0.0) -> int:
        raise NotImplementedError

    def pending_work(self, space_id: Optional[str] = None) -> int:
        raise NotImplementedError

    def work_queue_stats(self, space_id: Optional[str] = None,
                         latency_window: int = 20) -> dict:
        raise NotImplementedError

    def next_seq(self, space_id: str, operation_id: str) -> int:
        raise NotImplementedError

    def append_record(self, space_id: str, operation_id: str,
                      config_digest: str, action: str) -> RecordEntry:
        raise NotImplementedError

    def append_records(self, space_id: str, operation_id: str,
                       events: Sequence[Sequence[str]]) -> list:
        raise NotImplementedError

    def records_for(self, space_id: str,
                    operation_id: Optional[str] = None) -> list:
        raise NotImplementedError

    def records_since(self, space_id: str, after_rowid: int = 0,
                      limit: Optional[int] = None,
                      exclude_operation: Optional[str] = None,
                      upto_rowid: Optional[int] = None) -> list:
        raise NotImplementedError

    def last_record_rowid(self, space_id: str) -> int:
        raise NotImplementedError

    def has_record(self, space_id: str, config_digest: str,
                   include_failed: bool = False) -> bool:
        raise NotImplementedError

    def sampled_digests(self, space_id: str,
                        include_failed: bool = False) -> list:
        raise NotImplementedError

    def count_measured(self, space_id: Optional[str] = None) -> int:
        raise NotImplementedError

    def record_failure(self, config_digest: str, experiment_id: str,
                       phase: str, reason: str, attempts: int = 1,
                       cost: float = 0.0) -> None:
        """Persist structured provenance for one failed trial.

        Keyed on the configuration digest (like property values), not the
        space: the same non-deployable configuration failing in two related
        spaces is one fact about the configuration.  ``phase`` names the
        actuation lifecycle phase that gave up (``provision``/``run``/
        ``parse``, or ``measure`` for monolithic experiments), ``attempts``
        counts tries of that phase, and ``cost`` is the provisioned-but-
        unmeasured spend billed to the trial.  Legacy failed records written
        before this column existed surface with phase/reason ``"unknown"``
        from the read side (:meth:`failure_summary`).
        """
        raise NotImplementedError

    def failures_for(self, config_digest: str,
                     experiment_id: Optional[str] = None) -> list:
        """All failure rows for a digest, oldest first, as plain dicts
        ``{config_digest, experiment_id, phase, reason, attempts, cost,
        created_at}``."""
        raise NotImplementedError

    def failure_summary(self, space_id: str) -> dict:
        """Per-phase failure accounting for one space's *failed records*:
        ``{phase: {"count": n, "cost": total}}``.

        Joins the space's ``action='failed'`` record rows against the
        failure table; failed records with no structured row (written before
        the failure refactor, or by writers that bypassed
        ``record_failure``) are backfilled under phase ``"unknown"`` so
        legacy stores keep summing correctly.
        """
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    # -- derived conveniences (shared by every backend) ----------------------

    def put_configurations(self, configs: Sequence[Configuration]) -> list:
        """Intern a batch of configurations; returns their digests in order.

        Backends override this to coalesce the batch into one write
        transaction (SQLite) or one request round-trip (served store) —
        the hot path of ``DiscoverySpace.sample_batch``.
        """
        return [self.put_configuration(c) for c in configs]

    def get_configurations(self, digests: Sequence[str]) -> dict:
        """``{digest: Configuration}`` for every digest that exists.

        Backends override this to batch the misses (one IN query / one
        request frame); the fallback is a cache-assisted point-read loop.
        """
        out = {}
        for d in digests:
            config = self.get_configuration(d)
            if config is not None:
                out[d] = config
        return out

    def claim_work(self, owner: str, space_id: Optional[str] = None,
                   lease_s: float = DEFAULT_LEASE_S) -> Optional[dict]:
        """Atomically pop the single best queued work item (None when idle)."""
        batch = self.claim_work_batch(owner, limit=1, space_id=space_id,
                                      lease_s=lease_s)
        return batch[0] if batch else None

    def finish_work(self, item_id: str, action: str,
                    error: Optional[str] = None,
                    owner: Optional[str] = None) -> bool:
        """Land one claimed work item's outcome (see :meth:`finish_work_batch`)."""
        return self.finish_work_batch([(item_id, action, error)],
                                      owner=owner) == 1

    def wait_for_values(self, config_digest: str, experiment_id: str,
                        timeout_s: float = 60.0,
                        max_poll_s: float = 0.5) -> bool:
        """Wait for another investigator's in-flight measurement to land.

        Returns True when values appeared (reuse them), False when the claim
        vanished without values (the owner failed — take over) or the
        timeout expired (the owner is presumed dead — take over).

        Polling is exponential-backoff with full jitter, capped at
        ``max_poll_s``: the first checks come fast (a concurrent in-process
        measurement often lands in milliseconds), but a waiter stuck behind
        a minutes-long cloud measurement decays to ~2 polls/second instead
        of hammering the store — which matters at fleet scale, and doubly so
        for the served backend where every poll is a network round-trip.
        The jitter desynchronizes waiters that blocked on the same cell at
        the same moment, so their polls don't arrive in lockstep.
        """
        deadline = self.clock.monotonic() + timeout_s
        poll = 0.005
        while self.clock.monotonic() < deadline:
            has, claimed = self._poll_cell(config_digest, experiment_id)
            if has:
                return True
            if not claimed:
                return False
            remaining = deadline - self.clock.monotonic()
            # full jitter in (poll/2, poll], never sleeping past the deadline
            self.clock.sleep(min(max(remaining, 0.0),
                                 poll * (0.5 + 0.5 * random.random())))
            poll = min(poll * 2.0, max_poll_s)
        return self.has_values(config_digest, experiment_id)

    def _poll_cell(self, config_digest: str, experiment_id: str):
        """One ``wait_for_values`` probe: ``(has_values, claim_exists)``.

        A backend hook so remote stores can fuse both checks into a single
        round-trip (the served backend pipelines them); claim state is moot
        once values exist, so the second check is skipped on a hit here.
        """
        if self.has_values(config_digest, experiment_id):
            return True, True
        return False, self.claim_exists(config_digest, experiment_id)

    def iter_records_since(self, space_id: str, after_rowid: int = 0,
                           page_size: int = RECORD_PAGE_SIZE,
                           exclude_operation: Optional[str] = None,
                           ) -> Iterator[RecordEntry]:
        """Page through a space's record from a watermark, snapshot-bounded.

        The tail ``rowid`` is snapshotted ONCE up front and every page is
        bounded by it, so one sync observes a consistent prefix of the
        record no matter how fast concurrent writers append — the sync
        terminates after ``(tail - watermark) / page_size`` pages instead of
        chasing a moving tail.  Rows committing after the snapshot get
        higher rowids (commit-ordered allocation) and are picked up by the
        next sync.  Each page holds at most ``page_size`` decoded entries,
        which is what keeps a foreign-tell sync O(new rows) in *memory* as
        well as in I/O at 10⁶-record depth.

        After exhaustion the consumer's new watermark is the snapshot tail
        (see :meth:`consume_records_since`), even when the trailing rows
        were all ``exclude_operation``'s own.
        """
        tail = self.last_record_rowid(space_id)
        watermark = int(after_rowid)
        while watermark < tail:
            page = self.records_since(space_id, watermark, limit=page_size,
                                      exclude_operation=exclude_operation,
                                      upto_rowid=tail)
            yield from page
            if len(page) < page_size:
                break  # LIMIT not hit: the remaining range is exhausted
            watermark = page[-1].rowid

    def consume_records_since(self, space_id: str, after_rowid: int = 0,
                              page_size: int = RECORD_PAGE_SIZE,
                              exclude_operation: Optional[str] = None,
                              ):
        """(records, new_watermark): one snapshot-bounded paged read.

        The returned watermark is the snapshot tail — everything at or
        below it was either returned or excluded-by-request, so the caller
        can jump straight to it and never re-scan the range.
        """
        tail = self.last_record_rowid(space_id)
        if tail <= after_rowid:
            return [], int(after_rowid)
        records = list(self.iter_records_since(
            space_id, after_rowid, page_size=page_size,
            exclude_operation=exclude_operation))
        return records, tail

    # -- the immutable-configuration read cache ------------------------------

    #: lazily created per instance (subclasses need no __init__ cooperation)
    _config_cache: Optional[dict] = None

    def _config_get(self, digest: str) -> Optional[Configuration]:
        cache = self._config_cache
        return None if cache is None else cache.get(digest)

    def _config_put(self, digest: str, config: Configuration) -> None:
        cache = self._config_cache
        if cache is None:
            cache = self._config_cache = {}
        if len(cache) >= CONFIG_CACHE_MAX:
            # drop the oldest half (dict preserves insertion order): crude
            # but O(1) amortized, and misses only re-pay one point read
            for key in list(cache)[:CONFIG_CACHE_MAX // 2]:
                del cache[key]
        cache[digest] = config

    def invalidate_config_cache(self, digest: Optional[str] = None) -> None:
        """Explicit invalidation hook for the configuration read cache.

        Configurations are content-addressed and immutable, so routine
        writes never *need* this — ``put_configuration`` writes through.
        It exists for administrative surgery (a store file rewritten
        underneath a live handle) and for tests.
        """
        if self._config_cache is None:
            return
        if digest is None:
            self._config_cache.clear()
        else:
            self._config_cache.pop(digest, None)
