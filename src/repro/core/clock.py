"""Injectable time source for every timing-dependent subsystem.

Leases, stale-claim sweeps, queue-item re-queueing, autoscaling decisions,
and wait-for-values polling all read time through a :class:`Clock` instead
of the ``time`` module, so every timing behavior in the execution subsystem
is deterministically testable: the fault-injection and autoscaling suites
drive a :class:`FakeClock` forward by hand and observe reaping/scale
decisions without a single real sleep.

* :data:`SYSTEM_CLOCK` — the production clock (``time.time`` /
  ``time.monotonic`` / ``time.sleep``); a shared stateless singleton.
* :class:`FakeClock` — a thread-safe manual clock whose ``sleep`` *advances*
  virtual time instead of blocking, which makes timeout loops (e.g.
  ``SampleStore.wait_for_values``) terminate deterministically in tests.

Wall time (``time()``) stamps durable rows — claim leases, queue items —
because those timestamps must be comparable across processes and hosts
sharing one store.  Monotonic time (``monotonic()``) paces purely local
decisions: GC intervals, idle-worker retirement, latency EWMAs.
"""

from __future__ import annotations

import threading
import time as _time

__all__ = ["Clock", "FakeClock", "SYSTEM_CLOCK"]


class Clock:
    """The production time source; subclass to inject virtual time."""

    def time(self) -> float:
        """Wall-clock seconds (stamps rows shared across processes)."""
        return _time.time()

    def monotonic(self) -> float:
        """Monotonic seconds (paces local periodic decisions)."""
        return _time.monotonic()

    def sleep(self, seconds: float) -> None:
        _time.sleep(seconds)


SYSTEM_CLOCK = Clock()


class FakeClock(Clock):
    """A manual clock for deterministic timing tests.

    ``advance`` moves both wall and monotonic time forward; ``sleep``
    advances instead of blocking, so polling loops written against a
    :class:`Clock` run to their timeout instantly and deterministically.
    Thread-safe: worker threads in the property/fault suites share one
    instance with the test body.
    """

    def __init__(self, start: float = 1_000_000.0):
        self._lock = threading.Lock()
        self._now = float(start)

    def time(self) -> float:
        with self._lock:
            return self._now

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, seconds: float) -> None:
        self.advance(max(0.0, seconds))

    def advance(self, seconds: float) -> float:
        """Move virtual time forward; returns the new now."""
        with self._lock:
            self._now += float(seconds)
            return self._now
