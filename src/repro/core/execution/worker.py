"""Remote measurement worker: ``python -m repro.core.execution.worker``.

One worker process serving the ``work_items`` queue of a shared sample
store.  Start any number of these — on the investigator's host, on any
machine sharing the database file, or on any machine that can reach a
``python -m repro.core.store.server`` URL — and point them at a *factory*
that
rebuilds the Discovery Space (the store only persists Ω and experiment
identifiers; the experiment *code* must come from your module, exactly like
any ``multiprocessing`` target)::

    python -m repro.core.execution.worker \
        --store /mnt/shared/common_context.db \
        --factory mypackage.study:build_ds \
        --idle-timeout 30 --claim-batch 4

The factory is ``module:callable`` taking the store path and returning a
:class:`~repro.core.discovery.DiscoverySpace`.  The worker pops queued items
for that space *best-priority-first* — up to ``--claim-batch`` per store
round-trip, which amortizes slow-link latency — runs the measurement state
machine (values land through the normal measurement-claim arbitration, so
racing workers still measure each cell exactly once), lands the batch's
outcomes in one trip, and exits after ``--idle-timeout`` seconds without
work (or after ``--max-items``).

Liveness is heartbeat-based: a :class:`~repro.core.execution.base.LeasePacer`
thread renews the worker's claim + work-item leases every third of
``ds.lease_s``, so the investigator's GC reaps a silently dead worker in
seconds even when ``claim_timeout_s`` is minutes.
"""

from __future__ import annotations

import argparse
import importlib
import os
from typing import Optional

from .base import LeasePacer, run_measurement

__all__ = ["run_worker", "main"]


def run_worker(ds, owner: Optional[str] = None, idle_timeout_s: float = 10.0,
               max_items: Optional[int] = None,
               poll_interval_s: float = 0.05,
               claim_batch: int = 1,
               heartbeat: bool = True) -> int:
    """Serve the work-item queue of ``ds``'s store until idle; returns the
    number of items processed.  Importable directly so tests, embedded
    fleets, and :class:`~repro.core.execution.fleet.FleetSupervisor` threads
    can host the loop in-process.

    ``claim_batch`` items are popped per store round-trip (best-priority
    first) and their outcomes landed in one transaction; ``heartbeat=False``
    disables the lease pacer (the fault-injection tests use it to simulate
    a silently dead worker).
    """
    owner = owner or f"worker-{os.getpid()}"
    store = ds.store
    clock = ds.clock
    processed = 0
    # max_age_s: a measurement stuck past the claim timeout stops being
    # renewed, so the fleet recovers it (pre-lease recovery horizon).  A
    # claim batch shares one claimed_at, so the budget scales with the batch
    # — the tail item of a healthy N-item batch legitimately starts up to
    # (N-1) experiments after the claim.
    pacer = (LeasePacer(store, owner, ds.lease_s,
                        max_age_s=ds.claim_timeout_s * max(1, claim_batch))
             if heartbeat else None)
    if pacer is not None:
        pacer.start()
    try:
        idle_since = clock.monotonic()
        while max_items is None or processed < max_items:
            limit = max(1, claim_batch)
            if max_items is not None:
                limit = min(limit, max_items - processed)
            claims = store.claim_work_batch(owner, limit=limit,
                                            space_id=ds.space_id,
                                            lease_s=ds.lease_s)
            if not claims:
                if clock.monotonic() - idle_since >= idle_timeout_s:
                    break
                clock.sleep(poll_interval_s)
                continue
            outcomes = []
            for claim in claims:
                digest = claim["config_digest"]
                config = store.get_configuration(digest)
                if config is None:
                    outcomes.append((claim["item_id"], "failed",
                                     f"no stored configuration for digest {digest}"))
                    continue
                action, err = run_measurement(store, ds.actions.experiments,
                                              config, digest,
                                              ds.claim_timeout_s, owner=owner,
                                              lease_s=ds.lease_s)
                if action == "crashed":
                    # contain the experiment bug to this item; the worker
                    # survives and serves the rest of its batch
                    outcomes.append((claim["item_id"], "failed", f"crash: {err!r}"))
                else:
                    outcomes.append((claim["item_id"], action,
                                     None if err is None else str(err)))
            # guarded batch finish, one round-trip: items that went silent
            # long enough to be re-queued (and re-claimed by the surviving
            # fleet) are stale here and silently skipped — the re-execution's
            # outcome wins
            store.finish_work_batch(outcomes, owner=owner)
            processed += len(claims)
            idle_since = clock.monotonic()
    finally:
        if pacer is not None:
            pacer.stop()
    return processed


def _load_factory(spec: str):
    module_name, _, attr = spec.partition(":")
    if not attr:
        raise SystemExit(f"--factory must be module:callable, got {spec!r}")
    return getattr(importlib.import_module(module_name), attr)


def main(argv: Optional[list] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.execution.worker",
        description="Serve a shared sample store's work-item queue.")
    parser.add_argument("--store", required=True,
                        help="shared store identity: a database file path, "
                             "or a store-server URL (tcp://host:port / "
                             "unix:///path.sock) from "
                             "python -m repro.core.store.server")
    parser.add_argument("--factory", required=True,
                        help="module:callable rebuilding the DiscoverySpace "
                             "from the store path/URL (resolve it with "
                             "repro.core.store.open_store)")
    parser.add_argument("--idle-timeout", type=float, default=10.0,
                        help="exit after this many seconds without work")
    parser.add_argument("--max-items", type=int, default=None,
                        help="exit after processing this many items")
    parser.add_argument("--poll-interval", type=float, default=0.05,
                        help="queue poll period in seconds")
    parser.add_argument("--claim-batch", type=int, default=1,
                        help="work items claimed per store round-trip "
                             "(amortizes slow-link latency)")
    parser.add_argument("--no-heartbeat", action="store_true",
                        help="disable lease renewal (debugging only: the "
                             "worker will look dead after one lease)")
    parser.add_argument("--owner", default=None,
                        help="worker identity for claims (default: worker-<pid>)")
    args = parser.parse_args(argv)

    ds = _load_factory(args.factory)(args.store)
    processed = run_worker(ds, owner=args.owner,
                           idle_timeout_s=args.idle_timeout,
                           max_items=args.max_items,
                           poll_interval_s=args.poll_interval,
                           claim_batch=args.claim_batch,
                           heartbeat=not args.no_heartbeat)
    print(f"[worker pid={os.getpid()}] processed {processed} work items")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
