"""Remote measurement worker: ``python -m repro.core.execution.worker``.

One worker process serving the ``work_items`` queue of a shared sample
store.  Start any number of these — on the investigator's host or on any
machine sharing the database file — and point them at a *factory* that
rebuilds the Discovery Space (the store only persists Ω and experiment
identifiers; the experiment *code* must come from your module, exactly like
any ``multiprocessing`` target)::

    python -m repro.core.execution.worker \
        --store /mnt/shared/common_context.db \
        --factory mypackage.study:build_ds \
        --idle-timeout 30

The factory is ``module:callable`` taking the store path and returning a
:class:`~repro.core.discovery.DiscoverySpace`.  The worker claims queued
items for that space, runs the measurement state machine (values land
through the normal measurement-claim arbitration, so racing workers still
measure each cell exactly once), reports each outcome, and exits after
``--idle-timeout`` seconds without work (or after ``--max-items``).
"""

from __future__ import annotations

import argparse
import importlib
import os
import time
from typing import Optional

from .base import run_measurement

__all__ = ["run_worker", "main"]


def run_worker(ds, owner: Optional[str] = None, idle_timeout_s: float = 10.0,
               max_items: Optional[int] = None,
               poll_interval_s: float = 0.05) -> int:
    """Serve the work-item queue of ``ds``'s store until idle; returns the
    number of items processed.  Importable directly so tests and embedded
    fleets can host the loop in a thread instead of a process."""
    owner = owner or f"worker-{os.getpid()}"
    store = ds.store
    processed = 0
    idle_since = time.monotonic()
    while max_items is None or processed < max_items:
        claim = store.claim_work(owner, space_id=ds.space_id)
        if claim is None:
            if time.monotonic() - idle_since >= idle_timeout_s:
                break
            time.sleep(poll_interval_s)
            continue
        digest = claim["config_digest"]
        config = store.get_configuration(digest)
        if config is None:
            store.finish_work(claim["item_id"], "failed",
                              f"no stored configuration for digest {digest}",
                              owner=owner)
            continue
        action, err = run_measurement(store, ds.actions.experiments, config,
                                      digest, ds.claim_timeout_s, owner=owner)
        # guarded finish: if this item went silent long enough to be
        # re-queued (and re-claimed by the surviving fleet), our late
        # outcome is stale and must not overwrite the re-execution's
        if action == "crashed":
            # contain the experiment bug to this item; the worker survives
            store.finish_work(claim["item_id"], "failed", f"crash: {err!r}",
                              owner=owner)
        else:
            store.finish_work(claim["item_id"], action,
                              None if err is None else str(err), owner=owner)
        processed += 1
        idle_since = time.monotonic()
    return processed


def _load_factory(spec: str):
    module_name, _, attr = spec.partition(":")
    if not attr:
        raise SystemExit(f"--factory must be module:callable, got {spec!r}")
    return getattr(importlib.import_module(module_name), attr)


def main(argv: Optional[list] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.execution.worker",
        description="Serve a shared sample store's work-item queue.")
    parser.add_argument("--store", required=True,
                        help="path to the shared SampleStore database file")
    parser.add_argument("--factory", required=True,
                        help="module:callable rebuilding the DiscoverySpace "
                             "from the store path")
    parser.add_argument("--idle-timeout", type=float, default=10.0,
                        help="exit after this many seconds without work")
    parser.add_argument("--max-items", type=int, default=None,
                        help="exit after processing this many items")
    parser.add_argument("--poll-interval", type=float, default=0.05,
                        help="queue poll period in seconds")
    parser.add_argument("--owner", default=None,
                        help="worker identity for claims (default: worker-<pid>)")
    args = parser.parse_args(argv)

    ds = _load_factory(args.factory)(args.store)
    processed = run_worker(ds, owner=args.owner,
                           idle_timeout_s=args.idle_timeout,
                           max_items=args.max_items,
                           poll_interval_s=args.poll_interval)
    print(f"[worker pid={os.getpid()}] processed {processed} work items")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
