"""In-process and process-isolated execution backends.

* :class:`SerialBackend` — executes at submit time on the calling thread;
  the classic serial engine re-hosted behind the backend interface.
* :class:`ThreadBackend` — a thread pool (owned, or a caller-provided
  executor reused across batches); experiments share the interpreter, so a
  crashing experiment propagates like the pre-backend engine.
* :class:`ProcessBackend` — a persistent pool of worker processes.  A
  segfaulting, ``os._exit``-ing, or memory-leaking experiment poisons only
  its own slot: the worker's death is detected and attributed, its claims
  are released so waiters take over, the slot comes back as a ``failed``
  :class:`~repro.core.execution.base.WorkerCrashError` sample, and a
  replacement worker is respawned while the investigator (and the batch's
  other slots) keep going.
"""

from __future__ import annotations

import multiprocessing
import threading
import time
from collections import deque
from concurrent.futures import Executor, ThreadPoolExecutor
from typing import List, Optional

from ..actions import MeasurementError
from .base import (ExecutionBackend, ExecutionContext, WorkItem, WorkResult,
                   WorkerCrashError, run_measurement)

__all__ = ["SerialBackend", "ThreadBackend", "ProcessBackend"]


class SerialBackend(ExecutionBackend):
    """Execute each work item synchronously at submit time."""

    def __init__(self, ctx: ExecutionContext):
        self._ctx = ctx
        self._done: deque = deque()

    def submit(self, item: WorkItem) -> int:
        action, err = run_measurement(
            self._ctx.store, self._ctx.experiments, item.configuration,
            item.digest, self._ctx.claim_timeout_s)
        self._done.append(WorkResult(item, action, err))
        return item.tag

    def poll(self) -> List[WorkResult]:
        out = list(self._done)
        self._done.clear()
        return out

    @property
    def outstanding(self) -> int:
        return len(self._done)


class ThreadBackend(ExecutionBackend):
    """Fan work out over a thread pool (today's ``workers=N`` semantics)."""

    def __init__(self, ctx: ExecutionContext, workers: int = 4,
                 executor: Optional[Executor] = None):
        self._ctx = ctx
        self._borrowed = executor is not None
        self._pool = executor if executor is not None else ThreadPoolExecutor(
            max_workers=max(1, workers))
        self._lock = threading.Lock()
        self._done: deque = deque()
        self._inflight = 0

    def submit(self, item: WorkItem) -> int:
        with self._lock:
            self._inflight += 1
        fut = self._pool.submit(
            run_measurement, self._ctx.store, self._ctx.experiments,
            item.configuration, item.digest, self._ctx.claim_timeout_s)
        fut.add_done_callback(lambda f, item=item: self._finish(item, f))
        return item.tag

    def _finish(self, item: WorkItem, fut) -> None:
        action, err = fut.result()
        with self._lock:
            self._inflight -= 1
            self._done.append(WorkResult(item, action, err))

    def poll(self) -> List[WorkResult]:
        with self._lock:
            out = list(self._done)
            self._done.clear()
        return out

    @property
    def outstanding(self) -> int:
        with self._lock:
            return self._inflight + len(self._done)

    def close(self) -> None:
        if not self._borrowed:
            self._pool.shutdown(wait=False)


def _pool_worker(worker_id: int, task_queue, result_queue, store_path: str,
                 experiments, claim_timeout_s: float) -> None:
    """Worker-process main loop: serve the parent-assigned queue until the
    None sentinel.

    Opens its OWN store handle (processes must never share a SQLite
    connection).  The parent records each assignment *before* enqueueing it
    here, so an abrupt death (segfault, ``os._exit``, OOM-kill) at any point
    of the loop is attributable to exactly one item.  Never re-raises: an
    unexpected experiment error is reported as a crash outcome and the
    worker lives on to serve the next item.
    """
    from ..store import SampleStore

    store = SampleStore(store_path)
    while True:
        task = task_queue.get()
        if task is None:
            break
        tag, configuration, digest = task
        try:
            action, err = run_measurement(store, experiments, configuration,
                                          digest, claim_timeout_s)
        except BaseException as exc:  # pragma: no cover - run_measurement catches
            action, err = "crashed", exc
        if action == "crashed":
            result_queue.put(("done", worker_id, tag, "failed", "crash", repr(err)))
        elif err is not None:
            result_queue.put(("done", worker_id, tag, action, "measurement", str(err)))
        else:
            result_queue.put(("done", worker_id, tag, action, None, None))
    store.close()


class ProcessBackend(ExecutionBackend):
    """A persistent, crash-tolerant pool of worker processes.

    Crash isolation for hostile experiments: a segfaulting, ``os._exit``-ing,
    or OOM-killed experiment takes down one pool worker, not the
    investigator.  Items are dispatched parent-side — the assignment is
    recorded before the item reaches the worker's queue — so a death at any
    point is attributed to exactly one item: the parent releases the dead
    worker's measurement claims (so nobody stalls waiting on them), fails
    that one slot, and the next dispatch respawns replacement capacity — the
    ExpoCloud recipe, scaled to a local fleet.

    Workers are forked once and reused, so the per-measurement overhead is a
    queue hop, not a process launch.  Requires a file-backed store (children
    rendezvous through the database, never through a shared connection).
    Uses the ``fork`` start method where available — experiment callables
    need not be picklable — falling back to ``spawn`` elsewhere (experiments
    must then be importable/picklable, as with any ``multiprocessing`` use).
    """

    isolates_crashes = True

    def __init__(self, ctx: ExecutionContext, workers: int = 4,
                 mp_context=None):
        if ctx.store_path == ":memory:":
            raise ValueError(
                "ProcessBackend needs a file-backed SampleStore: worker "
                "processes rendezvous through the database file")
        self._ctx = ctx
        self._workers = max(1, workers)
        if mp_context is None:
            methods = multiprocessing.get_all_start_methods()
            mp_context = multiprocessing.get_context(
                "fork" if "fork" in methods else "spawn")
        self._mp = mp_context
        self._results = self._mp.SimpleQueue()
        self._pending: deque = deque()  # submitted, not yet assigned
        self._items: dict = {}          # tag -> WorkItem (outstanding)
        self._queues: dict = {}         # worker_id -> its task queue
        self._procs: dict = {}          # worker_id -> Process
        self._busy: dict = {}           # worker_id -> assigned tag
        self._idle: list = []           # worker_ids awaiting an assignment
        self._next_worker = 0
        self._closed = False

    def _spawn_worker(self) -> None:
        worker_id = self._next_worker
        self._next_worker += 1
        queue = self._mp.SimpleQueue()
        proc = self._mp.Process(
            target=_pool_worker,
            args=(worker_id, queue, self._results, self._ctx.store_path,
                  tuple(self._ctx.experiments), self._ctx.claim_timeout_s),
            daemon=True,
        )
        proc.start()
        self._queues[worker_id] = queue
        self._procs[worker_id] = proc
        self._idle.append(worker_id)

    def _dispatch(self) -> None:
        """Assign pending items to idle workers, growing the pool up to
        capacity.  The parent records the assignment BEFORE enqueueing, so a
        worker death at *any* point is attributable to exactly one item —
        nothing can be silently consumed and lost."""
        while (self._pending and not self._idle
               and len(self._procs) < self._workers):
            self._spawn_worker()
        while self._pending and self._idle:
            worker_id = self._idle.pop()
            item = self._pending.popleft()
            self._busy[worker_id] = item.tag
            self._queues[worker_id].put(
                (item.tag, item.configuration, item.digest))

    def submit(self, item: WorkItem) -> int:
        if self._closed:
            raise RuntimeError("backend is closed")
        self._items[item.tag] = item
        self._pending.append(item)
        self._dispatch()
        return item.tag

    def _drain_results(self, out: List[WorkResult]) -> None:
        while not self._results.empty():
            _, worker_id, tag, action, err_kind, message = self._results.get()
            if self._busy.get(worker_id) == tag:
                del self._busy[worker_id]
                self._idle.append(worker_id)
            item = self._items.pop(tag)
            if err_kind == "crash":
                err: Optional[BaseException] = WorkerCrashError(
                    f"experiment crashed in worker process: {message}")
            elif err_kind == "measurement":
                err = MeasurementError(message)
            else:
                err = None
            out.append(WorkResult(item, action, err))

    def poll(self) -> List[WorkResult]:
        out: List[WorkResult] = []
        self._drain_results(out)
        dead = [w for w, p in self._procs.items() if not p.is_alive()]
        if dead:
            # a worker may have reported its item *then* exited between the
            # two checks — drain again before attributing deaths
            self._drain_results(out)
            for worker_id in dead:
                proc = self._procs.pop(worker_id)
                self._queues.pop(worker_id).close()
                if worker_id in self._idle:
                    self._idle.remove(worker_id)
                proc.join()
                tag = self._busy.pop(worker_id, None)
                if tag is not None and tag in self._items:
                    # the assigned item died with its worker: release the
                    # dead pid's claims so waiters take over, poison only
                    # this slot
                    self._ctx.store.release_claims_owned_by(str(proc.pid))
                    item = self._items.pop(tag)
                    out.append(WorkResult(item, "failed", WorkerCrashError(
                        f"worker process pid={proc.pid} died with exit code "
                        f"{proc.exitcode} mid-measurement")))
        self._dispatch()
        return out

    @property
    def outstanding(self) -> int:
        return len(self._items)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for worker_id in self._procs:
            self._queues[worker_id].put(None)
        deadline = time.monotonic() + 5.0
        for proc in self._procs.values():
            proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if proc.is_alive():
                proc.terminate()
                proc.join()
        for queue in self._queues.values():
            queue.close()
        self._procs.clear()
        self._queues.clear()
        self._items.clear()
        self._busy.clear()
        self._idle.clear()
        self._pending.clear()
        self._results.close()
