"""In-process and process-isolated execution backends.

* :class:`SerialBackend` — executes at submit time on the calling thread;
  the classic serial engine re-hosted behind the backend interface.
* :class:`ThreadBackend` — a thread pool (owned, or a caller-provided
  executor reused across batches); experiments share the interpreter, so a
  crashing experiment propagates like the pre-backend engine.
* :class:`ProcessBackend` — a persistent, *autoscaling* pool of worker
  processes.  A segfaulting, ``os._exit``-ing, or memory-leaking experiment
  poisons only its own slot: the worker's death is detected and attributed,
  its claims are released so waiters take over, the slot comes back as a
  ``failed`` :class:`~repro.core.execution.base.WorkerCrashError` sample,
  and replacement capacity is respawned while the investigator (and the
  batch's other slots) keep going.  The fleet grows and shrinks between
  ``policy.min_workers`` and ``policy.max_workers`` from the observed
  backlog and the EWMA per-item latency (ExpoCloud-style), paced off the
  injected clock so scaling decisions are deterministically testable.
"""

from __future__ import annotations

import multiprocessing
import os
import threading
import time
from collections import deque
from concurrent.futures import Executor, ThreadPoolExecutor
from typing import List, Optional

from ..actions import MeasurementError
from .base import (AutoscalePolicy, ExecutionBackend, ExecutionContext,
                   LeasePacer, WorkItem, WorkResult, WorkerCrashError,
                   run_measurement)

__all__ = ["SerialBackend", "ThreadBackend", "ProcessBackend"]


class SerialBackend(ExecutionBackend):
    """Execute each work item synchronously at submit time."""

    def __init__(self, ctx: ExecutionContext):
        self._ctx = ctx
        self._done: deque = deque()

    def submit(self, item: WorkItem) -> int:
        action, err = run_measurement(
            self._ctx.store, self._ctx.experiments, item.configuration,
            item.digest, self._ctx.claim_timeout_s)
        self._done.append(WorkResult(item, action, err))
        return item.tag

    def poll(self) -> List[WorkResult]:
        out = list(self._done)
        self._done.clear()
        return out

    @property
    def outstanding(self) -> int:
        return len(self._done)


class ThreadBackend(ExecutionBackend):
    """Fan work out over a thread pool (today's ``workers=N`` semantics)."""

    def __init__(self, ctx: ExecutionContext, workers: int = 4,
                 executor: Optional[Executor] = None):
        self._ctx = ctx
        self._borrowed = executor is not None
        self._pool = executor if executor is not None else ThreadPoolExecutor(
            max_workers=max(1, workers))
        self._lock = threading.Lock()
        self._done: deque = deque()
        self._inflight = 0

    def submit(self, item: WorkItem) -> int:
        with self._lock:
            self._inflight += 1
        fut = self._pool.submit(
            run_measurement, self._ctx.store, self._ctx.experiments,
            item.configuration, item.digest, self._ctx.claim_timeout_s)
        fut.add_done_callback(lambda f, item=item: self._finish(item, f))
        return item.tag

    def _finish(self, item: WorkItem, fut) -> None:
        action, err = fut.result()
        with self._lock:
            self._inflight -= 1
            self._done.append(WorkResult(item, action, err))

    def poll(self) -> List[WorkResult]:
        with self._lock:
            out = list(self._done)
            self._done.clear()
        return out

    @property
    def outstanding(self) -> int:
        with self._lock:
            return self._inflight + len(self._done)

    def close(self) -> None:
        if not self._borrowed:
            self._pool.shutdown(wait=False)


def _pool_worker(worker_id: int, task_queue, result_queue, store_path: str,
                 experiments, claim_timeout_s: float,
                 lease_s: Optional[float] = None) -> None:
    """Worker-process main loop: serve the parent-assigned queue until the
    None sentinel.

    Opens its OWN store handle (processes must never share a SQLite
    connection) and heartbeats its measurement-claim leases on a
    :class:`LeasePacer`, so a worker that dies silently is reaped within
    ``lease_s`` even when ``claim_timeout_s`` is minutes.  The parent
    records each assignment *before* enqueueing it here, so an abrupt death
    (segfault, ``os._exit``, OOM-kill) at any point of the loop is
    attributable to exactly one item.  Never re-raises: an unexpected
    experiment error is reported as a crash outcome and the worker lives on
    to serve the next item.
    """
    from ..store import open_store

    store = open_store(store_path)
    pacer = (LeasePacer(store, str(os.getpid()), lease_s,
                        max_age_s=claim_timeout_s).start()
             if lease_s is not None else None)
    while True:
        task = task_queue.get()
        if task is None:
            break
        tag, configuration, digest = task
        try:
            action, err = run_measurement(store, experiments, configuration,
                                          digest, claim_timeout_s,
                                          lease_s=lease_s)
        except BaseException as exc:  # pragma: no cover - run_measurement catches
            action, err = "crashed", exc
        if action == "crashed":
            result_queue.put(("done", worker_id, tag, "failed", "crash", repr(err)))
        elif err is not None:
            result_queue.put(("done", worker_id, tag, action, "measurement", str(err)))
        else:
            result_queue.put(("done", worker_id, tag, action, None, None))
    if pacer is not None:
        pacer.stop()
    store.close()


class ProcessBackend(ExecutionBackend):
    """A persistent, crash-tolerant, autoscaling pool of worker processes.

    Crash isolation for hostile experiments: a segfaulting, ``os._exit``-ing,
    or OOM-killed experiment takes down one pool worker, not the
    investigator.  Items are dispatched parent-side — the assignment is
    recorded before the item reaches the worker's queue — so a death at any
    point is attributed to exactly one item: the parent releases the dead
    worker's measurement claims (so nobody stalls waiting on them), fails
    that one slot, and the next dispatch respawns replacement capacity — the
    ExpoCloud recipe, scaled to a local fleet.

    Autoscaling: the fleet is sized by an
    :class:`~repro.core.execution.base.AutoscalePolicy` (from
    ``ctx.autoscale``, or min 1 / max ``workers`` by default).  Sustained
    backlog grows the pool toward the policy target — latency-aware when
    the policy sets a drain horizon, using the EWMA per-item latency
    observed at dispatch/completion — and a worker idle longer than
    ``idle_retire_s`` is retired down to ``min_workers``.  All scaling
    decisions read ``ctx.clock``, so tests drive them with a fake clock:
    no sleeps, no flakes.

    Workers are forked once and reused, so the per-measurement overhead is a
    queue hop, not a process launch.  Requires a file-backed store (children
    rendezvous through the database, never through a shared connection).
    Uses the ``fork`` start method where available — experiment callables
    need not be picklable — falling back to ``spawn`` elsewhere (experiments
    must then be importable/picklable, as with any ``multiprocessing`` use).
    """

    isolates_crashes = True

    def __init__(self, ctx: ExecutionContext, workers: int = 4,
                 mp_context=None, policy: Optional[AutoscalePolicy] = None):
        if ctx.store_path == ":memory:":
            raise ValueError(
                "ProcessBackend needs a reopenable store — a database file "
                "path or a store-server URL: worker processes rendezvous "
                "through the shared store, never a shared connection")
        self._ctx = ctx
        self._clock = ctx.clock
        if policy is None:
            policy = ctx.autoscale
        if policy is None:
            policy = AutoscalePolicy(min_workers=1, max_workers=max(1, workers))
        self._policy = policy
        if mp_context is None:
            methods = multiprocessing.get_all_start_methods()
            mp_context = multiprocessing.get_context(
                "fork" if "fork" in methods else "spawn")
        self._mp = mp_context
        self._results = self._mp.SimpleQueue()
        self._pending: deque = deque()  # submitted, not yet assigned
        self._items: dict = {}          # tag -> WorkItem (outstanding)
        self._queues: dict = {}         # worker_id -> its task queue
        self._procs: dict = {}          # worker_id -> Process
        self._busy: dict = {}           # worker_id -> assigned tag
        self._idle: list = []           # worker_ids awaiting an assignment
        self._idle_since: dict = {}     # worker_id -> clock.monotonic()
        self._assigned_at: dict = {}    # worker_id -> clock.monotonic()
        self._retiring: list = []       # (proc, queue) sentinel sent, reaping
        self.ewma_latency_s: Optional[float] = None
        self._next_worker = 0
        self._closed = False

    @property
    def num_workers(self) -> int:
        """Live fleet size (observability + the autoscaling tests)."""
        return len(self._procs)

    def _spawn_worker(self) -> None:
        worker_id = self._next_worker
        self._next_worker += 1
        queue = self._mp.SimpleQueue()
        proc = self._mp.Process(
            target=_pool_worker,
            args=(worker_id, queue, self._results, self._ctx.store_path,
                  tuple(self._ctx.experiments), self._ctx.claim_timeout_s,
                  self._ctx.lease_s),
            daemon=True,
        )
        proc.start()
        self._queues[worker_id] = queue
        self._procs[worker_id] = proc
        self._idle.append(worker_id)
        self._idle_since[worker_id] = self._clock.monotonic()

    def _dispatch(self) -> None:
        """Assign pending items to idle workers, growing the pool toward the
        policy target for the observed backlog.  The parent records the
        assignment BEFORE enqueueing, so a worker death at *any* point is
        attributable to exactly one item — nothing can be silently consumed
        and lost."""
        backlog = len(self._pending) + len(self._busy)
        target = self._policy.target(backlog, self.ewma_latency_s)
        while (self._pending and not self._idle
               and len(self._procs) < target):
            self._spawn_worker()
        while self._pending and self._idle:
            worker_id = self._idle.pop()
            self._idle_since.pop(worker_id, None)
            item = self._pending.popleft()
            self._busy[worker_id] = item.tag
            self._assigned_at[worker_id] = self._clock.monotonic()
            self._queues[worker_id].put(
                (item.tag, item.configuration, item.digest))

    def _retire_idle(self) -> None:
        """Shrink: retire workers idle past the policy horizon, down to
        ``min_workers`` (a clean sentinel shutdown, not a kill — the worker
        finishes nothing because it is, by definition, idle).  Retirement is
        non-blocking: the sentinel is sent and the exiting process parked on
        a reap list that later polls (and close) collect, so the pipelined
        hot loop never stalls on a join."""
        for proc, queue in self._retiring[:]:
            if not proc.is_alive():
                proc.join()
                queue.close()
                self._retiring.remove((proc, queue))
        if not self._idle:
            return
        now = self._clock.monotonic()
        for worker_id in list(self._idle):
            if len(self._procs) <= self._policy.min_workers:
                break
            since = self._idle_since.get(worker_id)
            if since is None or now - since < self._policy.idle_retire_s:
                continue
            self._idle.remove(worker_id)
            self._idle_since.pop(worker_id, None)
            queue = self._queues.pop(worker_id)
            proc = self._procs.pop(worker_id)
            queue.put(None)
            self._retiring.append((proc, queue))

    def submit(self, item: WorkItem) -> int:
        if self._closed:
            raise RuntimeError("backend is closed")
        self._items[item.tag] = item
        self._pending.append(item)
        self._dispatch()
        return item.tag

    def _drain_results(self, out: List[WorkResult]) -> None:
        while not self._results.empty():
            _, worker_id, tag, action, err_kind, message = self._results.get()
            if self._busy.get(worker_id) == tag:
                del self._busy[worker_id]
                self._idle.append(worker_id)
                now = self._clock.monotonic()
                self._idle_since[worker_id] = now
                assigned = self._assigned_at.pop(worker_id, None)
                if assigned is not None:
                    self.ewma_latency_s = self._policy.smooth(
                        self.ewma_latency_s, now - assigned)
            item = self._items.pop(tag, None)
            if item is None:
                # the worker reported this item, then died before the next
                # poll could see the buffered result: its death was already
                # attributed and the slot failed — drop the late duplicate
                continue
            if err_kind == "crash":
                err: Optional[BaseException] = WorkerCrashError(
                    f"experiment crashed in worker process: {message}")
            elif err_kind == "measurement":
                err = MeasurementError(message)
            else:
                err = None
            out.append(WorkResult(item, action, err))

    def poll(self) -> List[WorkResult]:
        out: List[WorkResult] = []
        self._drain_results(out)
        dead = [w for w, p in self._procs.items() if not p.is_alive()]
        if dead:
            # a worker may have reported its item *then* exited between the
            # two checks — drain again before attributing deaths
            self._drain_results(out)
            for worker_id in dead:
                proc = self._procs.pop(worker_id)
                self._queues.pop(worker_id).close()
                if worker_id in self._idle:
                    self._idle.remove(worker_id)
                self._idle_since.pop(worker_id, None)
                self._assigned_at.pop(worker_id, None)
                proc.join()
                tag = self._busy.pop(worker_id, None)
                if tag is not None and tag in self._items:
                    # the assigned item died with its worker: release the
                    # dead pid's claims so waiters take over, poison only
                    # this slot
                    self._ctx.store.release_claims_owned_by(str(proc.pid))
                    item = self._items.pop(tag)
                    out.append(WorkResult(item, "failed", WorkerCrashError(
                        f"worker process pid={proc.pid} died with exit code "
                        f"{proc.exitcode} mid-measurement")))
        self._dispatch()
        self._retire_idle()
        return out

    @property
    def outstanding(self) -> int:
        return len(self._items)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for worker_id in self._procs:
            self._queues[worker_id].put(None)
        deadline = time.monotonic() + 5.0
        retiring_procs = [p for p, _ in self._retiring]
        for proc in list(self._procs.values()) + retiring_procs:
            proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if proc.is_alive():
                proc.terminate()
                proc.join()
        for queue in list(self._queues.values()) + [q for _, q in self._retiring]:
            queue.close()
        self._retiring.clear()
        self._procs.clear()
        self._queues.clear()
        self._items.clear()
        self._busy.clear()
        self._idle.clear()
        self._idle_since.clear()
        self._assigned_at.clear()
        self._pending.clear()
        self._results.close()
