"""Execution-backend interface + the measurement state machine.

``run_measurement`` is the claim/wait/steal state machine that used to live
inline in ``DiscoverySpace.sample_batch``: it is the *only* code path through
which an experiment is ever executed, regardless of backend, so the
measure-once guarantee (paper §III-D) holds identically for a thread in the
investigator, a forked child process, and a remote worker on another host.

An :class:`ExecutionBackend` is a small asynchronous work pool:

* :meth:`~ExecutionBackend.submit` accepts a :class:`WorkItem` and returns
  immediately (work may be queued internally until a slot frees);
* :meth:`~ExecutionBackend.poll` returns the :class:`WorkResult` list
  completed since the last poll, in completion order — the pipelined
  ask/tell driver consumes this;
* :meth:`~ExecutionBackend.drain` blocks until everything outstanding has
  completed — the barrier-synchronized batch driver consumes this.
"""

from __future__ import annotations

import abc
import math
import os
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from ..actions import FailureRecord, MeasurementError
from ..clock import Clock, SYSTEM_CLOCK
from ..entities import Configuration, PropertyValue

__all__ = ["WorkItem", "WorkResult", "ExecutionBackend", "ExecutionContext",
           "WorkerCrashError", "AutoscalePolicy", "LeasePacer",
           "run_measurement"]


class WorkerCrashError(MeasurementError):
    """A worker process died (or raised an unexpected error) mid-measurement.

    Subclasses :class:`MeasurementError` on purpose: under process isolation
    a crashing experiment poisons only its own slot — the driver records the
    slot as ``failed`` and the investigator survives, which is the point of
    running experiments out-of-process.
    """


@dataclass(frozen=True)
class WorkItem:
    """One unit of execution: measure all of A's experiments for a configuration.

    ``priority`` is the optimizer's acquisition score for the candidate
    (higher = more informative, 0.0 when unscored).  Queue-rendezvous
    workers pop best-first on it; in-process backends execute in submission
    order regardless, which keeps the serial engine byte-identical.
    """

    configuration: Configuration
    digest: str
    tag: int  # submission index; the driver maps results back through it
    priority: float = 0.0


@dataclass
class WorkResult:
    """Outcome of one work item: a sampling-record action tag + optional error.

    ``action`` follows the sampling-record vocabulary (``measured`` /
    ``reused`` / ``predicted`` / ``failed``) plus ``crashed`` for unexpected
    non-measurement errors, which in-process backends propagate to the caller
    exactly like the pre-backend engine did.
    """

    item: WorkItem
    action: str
    error: Optional[BaseException] = None


@dataclass(frozen=True)
class AutoscalePolicy:
    """When to grow and shrink a worker fleet (ExpoCloud-style).

    The policy is a pure function of observed queue state, so scaling
    decisions are deterministic and unit-testable: :meth:`target` maps a
    backlog (and optionally the EWMA per-item latency) to a desired fleet
    size between ``min_workers`` and ``max_workers``.

    * grow while the backlog per worker exceeds ``backlog_per_worker``;
    * with a ``drain_horizon_s`` and an observed per-item latency, size the
      fleet so the current backlog drains within the horizon
      (``backlog * latency / horizon`` workers) — latency-aware scaling;
    * shrink a worker that has been idle for ``idle_retire_s`` (paced off
      the injected clock, so tests drive retirement deterministically).
    """

    min_workers: int = 1
    max_workers: int = 4
    backlog_per_worker: float = 1.0
    idle_retire_s: float = 30.0
    ewma_alpha: float = 0.3
    drain_horizon_s: Optional[float] = None

    def __post_init__(self):
        if not 1 <= self.min_workers <= self.max_workers:
            raise ValueError(
                f"need 1 <= min_workers <= max_workers, got "
                f"{self.min_workers}..{self.max_workers}")

    def target(self, backlog: int, ewma_latency_s: Optional[float] = None) -> int:
        """Desired fleet size for a queue backlog (pure, deterministic)."""
        if self.drain_horizon_s and ewma_latency_s is not None:
            want = math.ceil(backlog * ewma_latency_s / self.drain_horizon_s)
        else:
            want = math.ceil(backlog / max(self.backlog_per_worker, 1e-9))
        return max(self.min_workers, min(self.max_workers, int(want)))

    def smooth(self, ewma: Optional[float], observed: float) -> float:
        """Fold one latency observation into the EWMA."""
        if ewma is None:
            return observed
        return (1.0 - self.ewma_alpha) * ewma + self.ewma_alpha * observed


@dataclass
class ExecutionContext:
    """What a backend needs to execute work: the common context and A.

    ``store`` is the investigator's handle; ``store_path`` is what
    out-of-process backends hand to children so they open their *own*
    connections (forked/spawned processes must never share a SQLite handle).

    ``claim_timeout_s`` is how long a waiter trusts *another* investigator's
    in-flight measurement (size it to the slowest experiment: minutes for
    cloud deployments); ``lease_s`` is the much shorter heartbeat lease a
    *live* owner keeps renewed — death detection is decoupled from
    experiment duration.  Lease expiry compares *wall-clock* timestamps
    written by different hosts, so on a multi-machine deployment ``lease_s``
    must exceed the heartbeat interval (lease_s/3) plus the worst expected
    clock skew between hosts (NTP drift); the default 15 s suits a single
    host or well-synced fleet — raise it (or QueueBackend's
    ``requeue_after_s`` grace) for loosely-synced clocks, trading slower
    death detection for no spurious reaping of live workers.  ``clock`` is
    the injectable time source every timing decision reads (leases, sweeps,
    autoscaling); ``autoscale``, when set, is the fleet-sizing policy
    backends that own workers apply.
    """

    store: "SampleStore"  # noqa: F821 - circular import avoided
    experiments: Sequence
    claim_timeout_s: float = 60.0
    space_id: str = ""
    lease_s: float = 15.0
    clock: Clock = field(default_factory=lambda: SYSTEM_CLOCK)
    autoscale: Optional[AutoscalePolicy] = None

    @property
    def store_path(self) -> str:
        return self.store.path


class LeasePacer:
    """Heartbeat thread: renews an owner's leases every ``interval_s``.

    Runs against real wall time (a daemon thread blocking on an Event), so a
    hung *process* stops beating and gets reaped — which is the point.
    ``max_age_s``, when set, is the hung-*thread* watchdog: rows older than
    it stop being renewed (see :meth:`SampleStore.renew_lease`), so a live
    process with a deadlocked measurement cannot keep its work claimed
    forever — workers pass their claim timeout.  Deterministic tests bypass
    the thread and call :meth:`beat` directly with a fake clock.  Idempotent
    start/stop; safe to use as a context manager around a measurement loop.
    """

    def __init__(self, store, owner: str, lease_s: float,
                 interval_s: Optional[float] = None,
                 max_age_s: Optional[float] = None):
        self._store = store
        self._owner = owner
        self._lease_s = lease_s
        self._interval_s = interval_s if interval_s is not None else lease_s / 3.0
        self._max_age_s = max_age_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def beat(self) -> int:
        """Renew now; returns the number of leases extended."""
        return self._store.renew_lease(self._owner, self._lease_s,
                                       max_age_s=self._max_age_s)

    def _run(self) -> None:
        while not self._stop.wait(self._interval_s):
            try:
                self.beat()
            except Exception:
                # a transient store error (e.g. "database is locked" past the
                # busy timeout under heavy contention) must not kill the
                # heartbeat for good — a silenced pacer makes a live worker
                # look dead, its items get re-executed, and its finishes are
                # rejected.  Skip the beat; the lease spans 3 intervals, so
                # one (or even two) missed beats never reap a live owner.
                continue

    def start(self) -> "LeasePacer":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name=f"lease-pacer-{self._owner}", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "LeasePacer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class ExecutionBackend(abc.ABC):
    """Asynchronous work pool with submit/poll/drain semantics."""

    #: True when a crashing experiment is contained to its slot (the driver
    #: then never sees ``crashed`` results from this backend).
    isolates_crashes = False

    @abc.abstractmethod
    def submit(self, item: WorkItem) -> int:
        """Accept a work item; returns its tag.  Never blocks on execution."""

    @abc.abstractmethod
    def poll(self) -> List[WorkResult]:
        """Results completed since the last poll, in completion order."""

    @property
    @abc.abstractmethod
    def outstanding(self) -> int:
        """Submitted items whose results have not been returned yet."""

    def drain(self, timeout_s: Optional[float] = None) -> List[WorkResult]:
        """Block until every outstanding item completes; return all results.

        Raises :class:`TimeoutError` when ``timeout_s`` elapses first (e.g. a
        queue backend with no live workers) — results gathered so far are
        attached to the exception as ``partial``.
        """
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        out: List[WorkResult] = []
        pause = 0.001
        while self.outstanding:
            got = self.poll()
            if got:
                out.extend(got)
                pause = 0.001
                continue
            if deadline is not None and time.monotonic() > deadline:
                err = TimeoutError(
                    f"drain timed out with {self.outstanding} work items outstanding"
                )
                err.partial = out  # type: ignore[attr-defined]
                raise err
            time.sleep(pause)
            pause = min(pause * 2, 0.05)
        out.extend(self.poll())
        return out

    def close(self) -> None:
        """Release pool resources (idempotent)."""

    def __enter__(self) -> "ExecutionBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def run_measurement(store, experiments, configuration: Configuration,
                    digest: str, claim_timeout_s: float = 60.0,
                    owner: Optional[str] = None,
                    lease_s: Optional[float] = None):
    """Measure every experiment in A for one configuration — the state machine.

    Returns ``(action, error)`` where ``action`` is the sampling-record tag.
    Reuse/measure decisions go through the common context; per-cell
    measurement claims arbitrate measure-once across every concurrent
    investigator (threads, processes, remote hosts) sharing ``store``:

    * win the claim → measure, land values, keep the claim (values make
      re-claiming moot);
    * lose it → wait for the winner's values; if the claim is released
      (owner failed) race to re-claim; if it goes stale (owner presumed
      dead) exactly one waiter steals it.

    ``lease_s`` sizes the claim's lease: heartbeating owners (queue/process
    workers running a :class:`LeasePacer`) pass their short heartbeat lease,
    non-heartbeating callers default to ``claim_timeout_s`` — the pre-lease
    reaping horizon.  Any failure between claiming and durably landing
    values releases the claim so waiters take over instead of stalling
    until their timeout.
    """
    owner = owner or str(os.getpid())
    claim_lease_s = lease_s if lease_s is not None else claim_timeout_s
    measured_any = reused_any = predicted_any = False
    try:
        for exp in experiments:
            if store.has_values(digest, exp.identifier):
                reused_any = True
                continue
            if exp.deferred:
                # apply-on-demand (A*_pred semantics, paper §IV-4)
                continue
            who = f"{owner}:{threading.get_ident()}"
            claimed = store.claim_experiment(digest, exp.identifier, who,
                                             lease_s=claim_lease_s)
            while not claimed:
                # Another investigator (thread or process) is already
                # measuring this cell: wait and reuse their result — the
                # measure-once guarantee.  Measure ONLY after winning a claim.
                if store.wait_for_values(digest, exp.identifier,
                                         timeout_s=claim_timeout_s):
                    break
                if store.claim_exists(digest, exp.identifier):
                    # timed out on a still-standing claim: the owner is
                    # presumed dead — exactly one waiter steals it
                    claimed = store.steal_claim(
                        digest, exp.identifier, who,
                        older_than_s=claim_timeout_s)
                else:
                    # owner failed and released: race for the re-claim
                    claimed = store.claim_experiment(
                        digest, exp.identifier, who, lease_s=claim_lease_s)
            if not claimed:
                reused_any = True
                continue
            try:
                # the claim is held until values durably land: any failure in
                # measuring, converting, or storing them must free the cell
                # so waiters take over instead of stalling until their timeout
                values = exp.measure(configuration)
                store.put_values(
                    digest,
                    [
                        PropertyValue(
                            name=k,
                            value=float(v),
                            experiment_id=exp.identifier,
                            predicted=exp.predicted,
                        )
                        for k, v in values.items()
                    ],
                )
            except MeasurementError as err:
                # persist structured failure provenance BEFORE releasing the
                # claim: the lifecycle attaches (phase, reason, attempts,
                # cost) to the exception, monolithic experiments get a
                # synthesized "measure" record.  Provenance is best-effort —
                # a store hiccup here must not turn a failed trial into a
                # crashed slot (nor mask the claim release below).
                rec = getattr(err, "failure", None) \
                    or FailureRecord("measure", str(err))
                try:
                    store.record_failure(digest, exp.identifier, rec.phase,
                                         rec.reason, rec.attempts, rec.cost)
                except Exception:
                    pass
                store.release_claim(digest, exp.identifier)
                raise
            except BaseException:
                store.release_claim(digest, exp.identifier)
                raise
            if exp.predicted:
                predicted_any = True
            else:
                measured_any = True
    except MeasurementError as err:
        return "failed", err
    except BaseException as err:
        # unexpected (an experiment bug, a store error): poison only this
        # slot — in-process backends re-raise it from the driver, isolating
        # backends convert it to a failed slot
        return "crashed", err
    if measured_any:
        return "measured", None
    if predicted_any and not reused_any:
        return "predicted", None
    return "reused", None
