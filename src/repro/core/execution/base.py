"""Execution-backend interface + the measurement state machine.

``run_measurement`` is the claim/wait/steal state machine that used to live
inline in ``DiscoverySpace.sample_batch``: it is the *only* code path through
which an experiment is ever executed, regardless of backend, so the
measure-once guarantee (paper §III-D) holds identically for a thread in the
investigator, a forked child process, and a remote worker on another host.

An :class:`ExecutionBackend` is a small asynchronous work pool:

* :meth:`~ExecutionBackend.submit` accepts a :class:`WorkItem` and returns
  immediately (work may be queued internally until a slot frees);
* :meth:`~ExecutionBackend.poll` returns the :class:`WorkResult` list
  completed since the last poll, in completion order — the pipelined
  ask/tell driver consumes this;
* :meth:`~ExecutionBackend.drain` blocks until everything outstanding has
  completed — the barrier-synchronized batch driver consumes this.
"""

from __future__ import annotations

import abc
import os
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from ..actions import MeasurementError
from ..entities import Configuration, PropertyValue

__all__ = ["WorkItem", "WorkResult", "ExecutionBackend", "ExecutionContext",
           "WorkerCrashError", "run_measurement"]


class WorkerCrashError(MeasurementError):
    """A worker process died (or raised an unexpected error) mid-measurement.

    Subclasses :class:`MeasurementError` on purpose: under process isolation
    a crashing experiment poisons only its own slot — the driver records the
    slot as ``failed`` and the investigator survives, which is the point of
    running experiments out-of-process.
    """


@dataclass(frozen=True)
class WorkItem:
    """One unit of execution: measure all of A's experiments for a configuration."""

    configuration: Configuration
    digest: str
    tag: int  # submission index; the driver maps results back through it


@dataclass
class WorkResult:
    """Outcome of one work item: a sampling-record action tag + optional error.

    ``action`` follows the sampling-record vocabulary (``measured`` /
    ``reused`` / ``predicted`` / ``failed``) plus ``crashed`` for unexpected
    non-measurement errors, which in-process backends propagate to the caller
    exactly like the pre-backend engine did.
    """

    item: WorkItem
    action: str
    error: Optional[BaseException] = None


@dataclass
class ExecutionContext:
    """What a backend needs to execute work: the common context and A.

    ``store`` is the investigator's handle; ``store_path`` is what
    out-of-process backends hand to children so they open their *own*
    connections (forked/spawned processes must never share a SQLite handle).
    """

    store: "SampleStore"  # noqa: F821 - circular import avoided
    experiments: Sequence
    claim_timeout_s: float = 60.0
    space_id: str = ""

    @property
    def store_path(self) -> str:
        return self.store.path


class ExecutionBackend(abc.ABC):
    """Asynchronous work pool with submit/poll/drain semantics."""

    #: True when a crashing experiment is contained to its slot (the driver
    #: then never sees ``crashed`` results from this backend).
    isolates_crashes = False

    @abc.abstractmethod
    def submit(self, item: WorkItem) -> int:
        """Accept a work item; returns its tag.  Never blocks on execution."""

    @abc.abstractmethod
    def poll(self) -> List[WorkResult]:
        """Results completed since the last poll, in completion order."""

    @property
    @abc.abstractmethod
    def outstanding(self) -> int:
        """Submitted items whose results have not been returned yet."""

    def drain(self, timeout_s: Optional[float] = None) -> List[WorkResult]:
        """Block until every outstanding item completes; return all results.

        Raises :class:`TimeoutError` when ``timeout_s`` elapses first (e.g. a
        queue backend with no live workers) — results gathered so far are
        attached to the exception as ``partial``.
        """
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        out: List[WorkResult] = []
        pause = 0.001
        while self.outstanding:
            got = self.poll()
            if got:
                out.extend(got)
                pause = 0.001
                continue
            if deadline is not None and time.monotonic() > deadline:
                err = TimeoutError(
                    f"drain timed out with {self.outstanding} work items outstanding"
                )
                err.partial = out  # type: ignore[attr-defined]
                raise err
            time.sleep(pause)
            pause = min(pause * 2, 0.05)
        out.extend(self.poll())
        return out

    def close(self) -> None:
        """Release pool resources (idempotent)."""

    def __enter__(self) -> "ExecutionBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def run_measurement(store, experiments, configuration: Configuration,
                    digest: str, claim_timeout_s: float = 60.0,
                    owner: Optional[str] = None):
    """Measure every experiment in A for one configuration — the state machine.

    Returns ``(action, error)`` where ``action`` is the sampling-record tag.
    Reuse/measure decisions go through the common context; per-cell
    measurement claims arbitrate measure-once across every concurrent
    investigator (threads, processes, remote hosts) sharing ``store``:

    * win the claim → measure, land values, keep the claim (values make
      re-claiming moot);
    * lose it → wait for the winner's values; if the claim is released
      (owner failed) race to re-claim; if it goes stale (owner presumed
      dead) exactly one waiter steals it.

    Any failure between claiming and durably landing values releases the
    claim so waiters take over instead of stalling until their timeout.
    """
    owner = owner or str(os.getpid())
    measured_any = reused_any = predicted_any = False
    try:
        for exp in experiments:
            if store.has_values(digest, exp.identifier):
                reused_any = True
                continue
            if exp.deferred:
                # apply-on-demand (A*_pred semantics, paper §IV-4)
                continue
            who = f"{owner}:{threading.get_ident()}"
            claimed = store.claim_experiment(digest, exp.identifier, who)
            while not claimed:
                # Another investigator (thread or process) is already
                # measuring this cell: wait and reuse their result — the
                # measure-once guarantee.  Measure ONLY after winning a claim.
                if store.wait_for_values(digest, exp.identifier,
                                         timeout_s=claim_timeout_s):
                    break
                if store.claim_exists(digest, exp.identifier):
                    # timed out on a still-standing claim: the owner is
                    # presumed dead — exactly one waiter steals it
                    claimed = store.steal_claim(
                        digest, exp.identifier, who,
                        older_than_s=claim_timeout_s)
                else:
                    # owner failed and released: race for the re-claim
                    claimed = store.claim_experiment(
                        digest, exp.identifier, who)
            if not claimed:
                reused_any = True
                continue
            try:
                # the claim is held until values durably land: any failure in
                # measuring, converting, or storing them must free the cell
                # so waiters take over instead of stalling until their timeout
                values = exp.measure(configuration)
                store.put_values(
                    digest,
                    [
                        PropertyValue(
                            name=k,
                            value=float(v),
                            experiment_id=exp.identifier,
                            predicted=exp.predicted,
                        )
                        for k, v in values.items()
                    ],
                )
            except BaseException:
                store.release_claim(digest, exp.identifier)
                raise
            if exp.predicted:
                predicted_any = True
            else:
                measured_any = True
    except MeasurementError as err:
        return "failed", err
    except BaseException as err:
        # unexpected (an experiment bug, a store error): poison only this
        # slot — in-process backends re-raise it from the driver, isolating
        # backends convert it to a failed slot
        return "crashed", err
    if measured_any:
        return "measured", None
    if predicted_any and not reused_any:
        return "predicted", None
    return "reused", None
