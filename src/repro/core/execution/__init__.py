"""Pluggable execution backends for Discovery Space measurements.

Architecture
------------

Every measurement in the system — serial ``sample``, barrier-synchronized
``sample_batch``, the pipelined ask/tell optimizer engine, RSSC's
representative measurement (④) and surrogate sweep (⑧) — executes through
one state machine and one interface:

* :func:`~repro.core.execution.base.run_measurement` is the claim/wait/steal
  state machine (extracted from the pre-backend ``sample_batch``): reuse
  stored values, else win a per-cell measurement claim and measure, else
  wait on the winner / steal from the dead.  It is the single code path that
  upholds the measure-once guarantee of paper §III-D, no matter where it
  runs.
* :class:`~repro.core.execution.base.ExecutionBackend` is an asynchronous
  work pool — ``submit(work_item) -> tag``, ``poll() -> completed results``
  (completion order, for pipelined drivers), ``drain() -> all results``
  (for barrier drivers).

Four backends implement the interface:

===================  ==========================================================
:class:`SerialBackend`   execute at submit time on the caller's thread (the
                         classic engine; byte-identical records)
:class:`ThreadBackend`   thread pool in the investigator process (today's
                         ``workers=N`` semantics; byte-identical records)
:class:`ProcessBackend`  one child process per measurement — a segfaulting or
                         leaking experiment poisons only its slot: its claims
                         are released, the slot records ``failed``, and the
                         investigator survives
:class:`QueueBackend`    store-rendezvous: work items are rows in the shared
                         SQLite store's ``work_items`` table; any number of
                         ``python -m repro.core.execution.worker`` processes
                         on any host pull items and land values through the
                         same claim arbitration (§III-D taken literally —
                         the store is the *only* coordination point), with
                         silent-worker re-queueing for crash tolerance
===================  ==========================================================

Layering: drivers (``DiscoverySpace.sample_batch``, the pipelined
``run_optimizer``) own *recording* — sampling-record events are appended by
the investigator, in submission order for the batch driver and completion
order for the pipelined driver — while backends own *execution*.  Workers
never write records; they only measure and land values, which is what lets
N investigators share one worker fleet without entangling their records.
"""

from .backends import ProcessBackend, SerialBackend, ThreadBackend
from .base import (ExecutionBackend, ExecutionContext, WorkItem, WorkResult,
                   WorkerCrashError, run_measurement)
from .queue import QueueBackend

__all__ = [
    "ExecutionBackend", "ExecutionContext", "WorkItem", "WorkResult",
    "WorkerCrashError", "run_measurement", "SerialBackend", "ThreadBackend",
    "ProcessBackend", "QueueBackend", "run_worker", "make_backend",
]

def __getattr__(name):
    # lazy: importing .worker eagerly would shadow `python -m
    # repro.core.execution.worker` (runpy's found-in-sys.modules warning)
    if name == "run_worker":
        from .worker import run_worker
        return run_worker
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_BACKENDS = {
    "serial": SerialBackend,
    "thread": ThreadBackend,
    "process": ProcessBackend,
    "queue": QueueBackend,
}


def make_backend(spec, ctx: ExecutionContext, workers: int = 1,
                 executor=None) -> ExecutionBackend:
    """Resolve a backend from a name, an instance, or legacy knobs.

    ``spec`` may be an :class:`ExecutionBackend` (returned as-is), one of
    ``"serial" | "thread" | "process" | "queue"``, or None — in which case
    the legacy ``workers``/``executor`` arguments pick serial vs thread
    exactly as the pre-backend engine did.
    """
    if isinstance(spec, ExecutionBackend):
        held = getattr(spec, "_ctx", None)
        if held is not None and ctx.space_id and held.space_id != ctx.space_id:
            # an instance carries its construction-time experiments; reusing
            # it on another space would execute the WRONG action space
            # (e.g. a surrogate sweep running the real experiments)
            raise ValueError(
                "execution backend was built for a different Discovery "
                "Space; resolve a fresh backend for this space (pass a "
                "backend name instead of an instance)")
        return spec
    if spec is None:
        if executor is not None:
            return ThreadBackend(ctx, executor=executor)
        if workers > 1:
            return ThreadBackend(ctx, workers=workers)
        return SerialBackend(ctx)
    if isinstance(spec, str):
        try:
            cls = _BACKENDS[spec]
        except KeyError:
            raise ValueError(
                f"unknown execution backend {spec!r}; "
                f"choose from {sorted(_BACKENDS)}") from None
        if cls is ThreadBackend:
            return ThreadBackend(ctx, workers=workers, executor=executor)
        if cls is ProcessBackend:
            return ProcessBackend(ctx, workers=workers)
        if cls is SerialBackend:
            return SerialBackend(ctx)
        return QueueBackend(ctx)
    raise TypeError(f"backend must be a name, ExecutionBackend, or None; "
                    f"got {type(spec).__name__}")
