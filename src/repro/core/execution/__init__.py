"""Pluggable execution backends for Discovery Space measurements.

Architecture
------------

Every measurement in the system — serial ``sample``, barrier-synchronized
``sample_batch``, the pipelined ask/tell optimizer engine, RSSC's
representative measurement (④) and surrogate sweep (⑧) — executes through
one state machine and one interface:

* :func:`~repro.core.execution.base.run_measurement` is the claim/wait/steal
  state machine (extracted from the pre-backend ``sample_batch``): reuse
  stored values, else win a per-cell measurement claim and measure, else
  wait on the winner / steal from the dead.  It is the single code path that
  upholds the measure-once guarantee of paper §III-D, no matter where it
  runs.
* :class:`~repro.core.execution.base.ExecutionBackend` is an asynchronous
  work pool — ``submit(work_item) -> tag``, ``poll() -> completed results``
  (completion order, for pipelined drivers), ``drain() -> all results``
  (for barrier drivers).

Four backends implement the interface:

===================  ==========================================================
:class:`SerialBackend`   execute at submit time on the caller's thread (the
                         classic engine; byte-identical records)
:class:`ThreadBackend`   thread pool in the investigator process (today's
                         ``workers=N`` semantics; byte-identical records)
:class:`ProcessBackend`  a persistent, *autoscaling* pool of worker
                         processes — a segfaulting or leaking experiment
                         poisons only its slot: its claims are released, the
                         slot records ``failed``, and the investigator
                         survives; the fleet grows under backlog and shrinks
                         back to ``min_workers`` when drained
:class:`QueueBackend`    store-rendezvous: work items are rows in the shared
                         SQLite store's ``work_items`` table; any number of
                         ``python -m repro.core.execution.worker`` processes
                         on any host pull items and land values through the
                         same claim arbitration (§III-D taken literally —
                         the store is the *only* coordination point), with
                         lease-based re-queueing for crash tolerance
===================  ==========================================================

Priorities, leases, autoscaling
-------------------------------

Three cooperating mechanisms turn the queue into a scheduler rather than a
pipe:

* **Priorities** — every :class:`WorkItem` carries the optimizer's
  acquisition score; ``QueueBackend`` writes it into the ``work_items`` row
  and workers pop best-first (FIFO within ties), so the most informative
  configurations are measured earliest (Lynceus-style early convergence).
  Workers claim up to N items per store round-trip and land the batch's
  outcomes in one transaction, amortizing slow-link latency.
* **Leases** — claims and running work items are heartbeat-leased: the
  owner renews via :meth:`SampleStore.renew_lease` on a
  :class:`~repro.core.execution.base.LeasePacer` thread, so
  ``claim_timeout_s`` can be minutes for long cloud measurements while a
  silently dead owner is reaped within seconds by ``sweep_stale_claims`` /
  ``requeue_stale_work``.  A reaped owner's late ``finish_work`` is
  rejected by the owner guard, so re-executions are never overwritten.
* **Autoscaling** — an :class:`~repro.core.execution.base.AutoscalePolicy`
  (exposed on :class:`ExecutionContext`) maps observed backlog + EWMA
  per-item latency to a fleet size; ``ProcessBackend`` applies it to its
  own pool and :class:`~repro.core.execution.fleet.FleetSupervisor` applies
  it to a store-rendezvous queue fleet (ExpoCloud-style).

Every timing decision reads the injectable
:class:`~repro.core.clock.Clock` on the context, which is what makes the
lease fault-injection and autoscaling suites deterministic.

Layering: drivers (``DiscoverySpace.sample_batch``, the pipelined
``run_optimizer``) own *recording* — sampling-record events are appended by
the investigator, in submission order for the batch driver and completion
order for the pipelined driver — while backends own *execution*.  Workers
never write records; they only measure and land values, which is what lets
N investigators share one worker fleet without entangling their records.
"""

from .backends import ProcessBackend, SerialBackend, ThreadBackend
from .base import (AutoscalePolicy, ExecutionBackend, ExecutionContext,
                   LeasePacer, WorkItem, WorkResult, WorkerCrashError,
                   run_measurement)
from .queue import QueueBackend

__all__ = [
    "ExecutionBackend", "ExecutionContext", "WorkItem", "WorkResult",
    "WorkerCrashError", "AutoscalePolicy", "LeasePacer", "run_measurement",
    "SerialBackend", "ThreadBackend", "ProcessBackend", "QueueBackend",
    "run_worker", "FleetSupervisor", "make_backend",
]

def __getattr__(name):
    # lazy: importing .worker (or .fleet, which imports it) eagerly would
    # shadow `python -m repro.core.execution.worker` (runpy's
    # found-in-sys.modules warning)
    if name == "run_worker":
        from .worker import run_worker
        return run_worker
    if name == "FleetSupervisor":
        from .fleet import FleetSupervisor
        return FleetSupervisor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_BACKENDS = {
    "serial": SerialBackend,
    "thread": ThreadBackend,
    "process": ProcessBackend,
    "queue": QueueBackend,
}


def make_backend(spec, ctx: ExecutionContext, workers: int = 1,
                 executor=None) -> ExecutionBackend:
    """Resolve a backend from a name, an instance, or legacy knobs.

    ``spec`` may be an :class:`ExecutionBackend` (returned as-is), one of
    ``"serial" | "thread" | "process" | "queue"``, or None — in which case
    the legacy ``workers``/``executor`` arguments pick serial vs thread
    exactly as the pre-backend engine did.
    """
    if isinstance(spec, ExecutionBackend):
        held = getattr(spec, "_ctx", None)
        if held is not None and ctx.space_id and held.space_id != ctx.space_id:
            # an instance carries its construction-time experiments; reusing
            # it on another space would execute the WRONG action space
            # (e.g. a surrogate sweep running the real experiments)
            raise ValueError(
                "execution backend was built for a different Discovery "
                "Space; resolve a fresh backend for this space (pass a "
                "backend name instead of an instance)")
        return spec
    if spec is None:
        if executor is not None:
            return ThreadBackend(ctx, executor=executor)
        if workers > 1:
            return ThreadBackend(ctx, workers=workers)
        return SerialBackend(ctx)
    if isinstance(spec, str):
        try:
            cls = _BACKENDS[spec]
        except KeyError:
            raise ValueError(
                f"unknown execution backend {spec!r}; "
                f"choose from {sorted(_BACKENDS)}") from None
        if cls is ThreadBackend:
            return ThreadBackend(ctx, workers=workers, executor=executor)
        if cls is ProcessBackend:
            return ProcessBackend(ctx, workers=workers)
        if cls is SerialBackend:
            return SerialBackend(ctx)
        return QueueBackend(ctx)
    raise TypeError(f"backend must be a name, ExecutionBackend, or None; "
                    f"got {type(spec).__name__}")
