"""Store-rendezvous execution: the ``work_items`` queue (paper §III-D).

The paper's distributed-investigation claim is that the shared sample store
is the *only* coordination point between investigators.  :class:`QueueBackend`
takes that literally for execution too: ``submit`` writes a row to the
``work_items`` table of the SQLite :class:`~repro.core.store.SampleStore`,
and any number of worker processes — on this host or on any host sharing the
database — pull items with ``python -m repro.core.execution.worker``, run the
measurement state machine, and land values through the existing
measurement-claim arbitration.  The investigator polls the table for
outcomes; it never talks to a worker directly.

Crash tolerance (ExpoCloud-style): workers heartbeat their leases, so a
worker that dies mid-item stops renewing; the backend periodically re-queues
rows whose lease expired — within seconds, even when the claim timeout is
minutes — so the surviving fleet redoes the work, and sweeps the dead
worker's stale measurement claims so nobody stalls waiting on them.

Scheduling (Lynceus-style): ``submit`` forwards the work item's ``priority``
(the optimizer's acquisition score) into the queue row, and workers pop
best-first — the most informative configurations are measured earliest,
which is what lets a budget-constrained exploration converge early.
"""

from __future__ import annotations

from typing import List, Optional

from ..actions import MeasurementError
from .base import (ExecutionBackend, ExecutionContext, WorkItem, WorkResult,
                   WorkerCrashError)

__all__ = ["QueueBackend"]


class QueueBackend(ExecutionBackend):
    """Dispatch work through the store's ``work_items`` table to remote workers.

    Requires a file-backed store and at least one live worker process (see
    :mod:`repro.core.execution.worker`); with none, :meth:`drain` blocks
    until ``drain_timeout_s`` and raises :class:`TimeoutError` — set it
    whenever the worker fleet is not under this process's control (the
    default None waits forever, on the §III-D premise that workers may join
    late).  Results carry the action tag the remote state machine reported;
    a crash on the worker side surfaces as a ``failed`` slot with
    :class:`WorkerCrashError`.
    """

    isolates_crashes = True

    def __init__(self, ctx: ExecutionContext, requeue_after_s: Optional[float] = None,
                 drain_timeout_s: Optional[float] = None):
        if ctx.store_path == ":memory:":
            raise ValueError(
                "QueueBackend needs a reopenable store — a database file "
                "path or a store-server URL: remote workers rendezvous "
                "through the shared store")
        self._ctx = ctx
        # Grace period past lease expiry before re-queueing (0 = re-queue the
        # moment a heartbeat lease lapses; raise it for jittery networks).
        self._requeue_after_s = requeue_after_s or 0.0
        self._drain_timeout_s = drain_timeout_s
        self._open: dict = {}  # item_id -> WorkItem
        # GC paces off the injected clock — and the first poll sweeps
        # immediately, so even sub-second runs (--quick benches, CI smoke
        # tests) get at least one garbage-collection pass.
        self._last_sweep: Optional[float] = None

    def drain(self, timeout_s: Optional[float] = None):
        return super().drain(timeout_s if timeout_s is not None
                             else self._drain_timeout_s)

    def submit(self, item: WorkItem) -> int:
        item_id = self._ctx.store.enqueue_work(self._ctx.space_id, item.digest,
                                               priority=item.priority)
        self._open[item_id] = item
        return item.tag

    def poll(self) -> List[WorkResult]:
        results = self._ctx.store.fetch_work_results(list(self._open))
        out: List[WorkResult] = []
        for item_id, (action, error) in results.items():
            item = self._open.pop(item_id)
            err: Optional[BaseException] = None
            if action == "failed" and error is not None:
                err = (WorkerCrashError(error) if error.startswith("crash:")
                       else MeasurementError(error))
            out.append(WorkResult(item, action, err))
        self._maybe_gc()
        return out

    def _maybe_gc(self) -> None:
        """Periodic fleet hygiene while waiting: re-queue items whose worker
        stopped heartbeating and reap its stale measurement claims.  Paced
        off the injected clock at half the lease horizon, so dead owners are
        reaped within ~1.5 leases — seconds, not claim timeouts."""
        now = self._ctx.clock.monotonic()
        period = min(1.0, self._ctx.lease_s / 2)
        if self._last_sweep is not None and now - self._last_sweep < period:
            return
        self._last_sweep = now
        self._ctx.store.requeue_stale_work(grace_s=self._requeue_after_s)
        self._ctx.store.sweep_stale_claims()

    @property
    def outstanding(self) -> int:
        return len(self._open)
