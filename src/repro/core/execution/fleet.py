"""Autoscaling supervision for store-rendezvous worker fleets.

:class:`FleetSupervisor` manages a fleet of queue workers (each an
in-process thread hosting the :func:`~repro.core.execution.worker.run_worker`
claim loop against its own :class:`~repro.core.discovery.DiscoverySpace`
handle) and sizes it ExpoCloud-style from two observations it reads out of
the shared store — queue depth and the EWMA of per-item claim→finish
latency:

* :meth:`step` is one supervision round: observe, fold the latency into the
  EWMA, compute the :class:`~repro.core.execution.base.AutoscalePolicy`
  target, grow the fleet toward it, and — once the queue has stayed drained
  for ``idle_retire_s`` — shrink back to ``min_workers``.  It also performs
  fleet hygiene: re-queueing items whose owner stopped heartbeating and
  sweeping their stale measurement claims.
* :meth:`run` loops ``step`` until a wall-clock budget expires (the CI
  queue-soak entry point); tests call ``step`` directly under a
  :class:`~repro.core.clock.FakeClock` for deterministic scale decisions.

The store remains the *only* coordination point (paper §III-D): the
supervisor never talks to an investigator — any number of investigators can
submit prioritized work while one supervisor keeps the fleet sized to the
backlog.
"""

from __future__ import annotations

import threading
import uuid
from typing import Callable, Optional

from ..clock import Clock
from .base import AutoscalePolicy, LeasePacer
from .worker import run_worker

__all__ = ["FleetSupervisor"]


class FleetSupervisor:
    """Grow/shrink a fleet of queue-worker threads from observed queue state.

    ``ds_factory`` rebuilds the Discovery Space (each worker gets its own
    handle, exactly like a remote worker process would); ``policy`` defaults
    to the space's ``autoscale`` policy or a 1–4 worker default.
    """

    def __init__(self, ds_factory: Callable[[], "DiscoverySpace"],  # noqa: F821
                 policy: Optional[AutoscalePolicy] = None,
                 clock: Optional[Clock] = None,
                 claim_batch: int = 2,
                 poll_interval_s: float = 0.02,
                 name: Optional[str] = None):
        self._ds_factory = ds_factory
        ds = ds_factory()
        self._store = ds.store
        self._space_id = ds.space_id
        self._clock = clock if clock is not None else ds.clock
        self._policy = (policy if policy is not None
                        else getattr(ds, "autoscale", None) or AutoscalePolicy())
        self._claim_batch = claim_batch
        self._poll_interval_s = poll_interval_s
        # Owner names must be store-unique: two supervisors sharing one
        # store with colliding worker owners would cross-renew each other's
        # leases (a live fleet keeping a dead fleet's items "running").
        self._name = name if name is not None else f"fleet-{uuid.uuid4().hex[:8]}"
        self._workers: list = []  # (owner, thread, stop_event)
        self._lock = threading.Lock()
        self._processed = 0
        self._next_id = 0
        self._idle_since: Optional[float] = None
        self.ewma_latency_s: Optional[float] = None

    # -- fleet membership ---------------------------------------------------

    @property
    def num_workers(self) -> int:
        """Live fleet size.  Threads that died unexpectedly (an experiment
        or store error escaping ``run_worker``) are pruned here, so the next
        :meth:`step` sees real capacity and respawns toward the target
        instead of counting corpses."""
        self._workers = [w for w in self._workers if w[1].is_alive()]
        return len(self._workers)

    @property
    def processed(self) -> int:
        """Total work items executed by this fleet so far."""
        with self._lock:
            return self._processed

    def _serve(self, ds, owner: str, stop_event: threading.Event) -> None:
        """Worker-thread body: drain-claim-measure rounds until told to stop.

        One lease pacer covers the whole thread (claims + running items), so
        heartbeats continue across rounds; the inner ``run_worker`` call runs
        with ``idle_timeout_s=0`` — process everything claimable, then yield.
        """
        # the age budget scales with the claim batch: a batch shares one
        # claimed_at, so its tail item starts up to (N-1) experiments late
        with LeasePacer(ds.store, owner, ds.lease_s,
                        max_age_s=ds.claim_timeout_s * max(1, self._claim_batch)):
            while not stop_event.is_set():
                n = run_worker(ds, owner=owner, idle_timeout_s=0.0,
                               poll_interval_s=self._poll_interval_s,
                               claim_batch=self._claim_batch,
                               heartbeat=False)
                if n:
                    with self._lock:
                        self._processed += n
                else:
                    stop_event.wait(self._poll_interval_s)

    def _spawn(self) -> str:
        owner = f"{self._name}-w{self._next_id}"
        self._next_id += 1
        ds = self._ds_factory()
        stop_event = threading.Event()
        thread = threading.Thread(target=self._serve, args=(ds, owner, stop_event),
                                  name=owner, daemon=True)
        thread.start()
        self._workers.append((owner, thread, stop_event))
        return owner

    def _stop_one(self) -> None:
        owner, thread, stop_event = self._workers.pop()
        stop_event.set()
        thread.join(timeout=10.0)

    # -- supervision --------------------------------------------------------

    def step(self) -> dict:
        """One supervision round; returns the observability snapshot.

        Deterministic given the store state and the injected clock: the
        autoscaling tests drive this directly with a fake clock — no sleeps.
        """
        # fleet hygiene first: a dead worker's items go back to the queue
        # (counting toward the backlog this round) and its claims are swept
        requeued = self._store.requeue_stale_work()
        self._store.sweep_stale_claims()

        stats = self._store.work_queue_stats(self._space_id)
        if stats["recent_latency_s"] is not None:
            self.ewma_latency_s = self._policy.smooth(
                self.ewma_latency_s, stats["recent_latency_s"])
        backlog = stats["queued"] + stats["running"]
        target = self._policy.target(backlog, self.ewma_latency_s)

        now = self._clock.monotonic()
        if backlog > 0:
            self._idle_since = None
        elif self._idle_since is None:
            self._idle_since = now

        while self.num_workers < target:
            self._spawn()
        if (backlog == 0 and self._idle_since is not None
                and now - self._idle_since >= self._policy.idle_retire_s):
            while self.num_workers > self._policy.min_workers:
                self._stop_one()

        return {"workers": self.num_workers, "target": target,
                "backlog": backlog, "requeued": requeued,
                "ewma_latency_s": self.ewma_latency_s,
                "processed": self.processed, **stats}

    def run(self, budget_s: float, step_interval_s: float = 0.2) -> dict:
        """Supervise for ``budget_s`` seconds, then stop the fleet.

        The soak/CI entry point: keeps stepping on ``step_interval_s`` until
        the budget expires; returns the final snapshot.
        """
        deadline = self._clock.monotonic() + budget_s
        snapshot = self.step()
        try:
            while self._clock.monotonic() < deadline:
                self._clock.sleep(step_interval_s)
                snapshot = self.step()
        finally:
            self.stop()
        return snapshot

    def start(self) -> "FleetSupervisor":
        """Pre-warm the fleet to ``min_workers`` (optional; ``step`` grows on
        demand anyway)."""
        while self.num_workers < self._policy.min_workers:
            self._spawn()
        return self

    def stop(self) -> None:
        """Stop every worker thread (idempotent)."""
        while self._workers:
            self._stop_one()

    def __enter__(self) -> "FleetSupervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
