"""Spec-driven CLI: run investigations and inspect the space catalog.

::

    python -m repro.core.api run spec.json [--store PATH] [--dry-run]
                                           [--resume] [--out RESULT.json]
    python -m repro.core.api validate spec.json
    python -m repro.core.api catalog --store PATH
    python -m repro.core.api frontier --store PATH --space ID \
                                      --properties cost,p95 [--modes min,min]
    python -m repro.core.api record-trace spec.json --out trace.jsonl \
                                          [--n 50] [--seed 0]

``run`` executes the spec end to end over the given store (a fresh
in-memory store when omitted — fine for self-contained smoke specs, useless
for transfer, which needs the store holding the source data).  ``--dry-run``
prints the :meth:`~repro.core.api.investigation.Investigation.plan` —
engine dispatch, fleet, budget, and which catalog spaces transfer would
warm-start from — without measuring anything.  ``validate`` parses the spec
(strict: unknown fields and schema-version mismatches fail) and re-emits
its canonical JSON.  ``catalog`` lists every registered space in a store
with its measurement counts.  ``record-trace`` measures N sampled
configurations through the spec's first experiment/connector and captures
the actuation trace (phase outcomes, durations, retries, properties) to a
JSONL file replayable via the ``trace-replay`` factory — pay for a sweep
once, replay it forever.
"""

from __future__ import annotations

import argparse
import json
import sys

from ..store import open_store
from .catalog import SpaceCatalog
from .investigation import Investigation
from .spec import InvestigationSpec


def _load_spec(path: str) -> InvestigationSpec:
    try:
        return InvestigationSpec.load(path)
    except (ValueError, KeyError, OSError, json.JSONDecodeError) as err:
        raise SystemExit(f"error: bad spec {path!r}: {err}")


def _cmd_run(args) -> int:
    spec = _load_spec(args.spec)
    store = open_store(args.store) if args.store else None
    inv = Investigation(spec, store=store)
    plan = inv.plan()
    print(plan.describe())
    if args.dry_run:
        return 0
    result = inv.run(resume=args.resume)
    summary = result.summary()
    print(f"\ninvestigation {spec.name!r} finished: "
          f"{summary['trials']} trials, "
          f"{summary['paid_measurements']} paid measurements", end="")
    if result.transfer is not None and result.transfer.applied:
        print(f" (transfer from {result.transfer.source_space_id[:12]}…: "
              f"{result.transfer.n_warm_trials} warm trials, "
              f"{result.transfer.paid} paid representatives)", end="")
    print()
    if spec.objective is not None and spec.objective.constraints:
        bounds = ", ".join(c.describe() for c in spec.objective.constraints)
        print(f"SLA: {bounds} — {summary['infeasible']} of "
              f"{summary['trials']} trials infeasible")
    best = summary["best"]
    if best is not None:
        label = "feasible " if summary["infeasible"] else ""
        print(f"best {label}{spec.objective_label()} = {best['value']:.4g} "
              f"at {best['configuration']}")
    elif spec.objective is not None and spec.objective.constraints:
        print("no feasible configuration found within budget")
    q = summary["prediction_quality"]
    if q is not None:
        print(f"prediction quality (surrogate vs later measurements): {q}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True, default=str)
        print(f"wrote {args.out}")
    return 0


def _cmd_validate(args) -> int:
    spec = _load_spec(args.spec)
    roundtrip = InvestigationSpec.loads(spec.dumps())
    assert roundtrip == spec, "spec does not round-trip"  # defensive
    print(spec.dumps())
    return 0


def _cmd_catalog(args) -> int:
    catalog = SpaceCatalog(open_store(args.store))
    entries = catalog.entries()
    if not entries:
        print("catalog is empty")
        return 0
    for e in entries:
        s = e.summary()
        print(f"{e.space_id}  dims={','.join(s['dimensions'])} "
              f"size={s['size']} properties={','.join(s['properties']) or '?'}"
              f" records={s['records']} measured={s['measured']}")
    return 0


def _cmd_frontier(args) -> int:
    properties = [p for p in args.properties.split(",") if p]
    modes = None
    if args.modes:
        modes = [m for m in args.modes.split(",") if m]
    store = open_store(args.store)
    front = store.frontier(args.space, properties, modes)
    if not front:
        print("frontier is empty (no configuration has measured values for "
              "every requested property)")
        return 0
    header = "  ".join(f"{p:>14}" for p in properties)
    print(f"{header}  configuration")
    for config, values in front:
        cells = "  ".join(f"{v:>14.6g}" for v in values)
        print(f"{cells}  {config.as_dict()}")
    print(f"{len(front)} non-dominated point(s)")
    return 0


def _cmd_record_trace(args) -> int:
    import numpy as np

    from ..connector import record_trace

    spec = _load_spec(args.spec)
    experiments = [e.build() for e in spec.experiments] \
        + [c.build() for c in spec.connectors]
    if not experiments:
        raise SystemExit("error: spec names no experiments/connectors "
                         "to record")
    experiment = experiments[0]
    rng = np.random.default_rng(args.seed)
    configs = spec.space.sample_configurations(rng, args.n)
    header, trials = record_trace(experiment, configs, path=args.out)
    ok = sum(1 for t in trials if t["properties"] is not None)
    print(f"recorded {len(trials)} trial(s) from {experiment.identifier} "
          f"({ok} ok, {len(trials) - ok} failed) -> {args.out}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.api",
        description="Declarative Investigation runner + space-catalog tool")
    sub = parser.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="execute an InvestigationSpec")
    p_run.add_argument("spec", help="path to the spec JSON")
    p_run.add_argument("--store", default=None,
                       help="store path or server URL (tcp://host:port / "
                            "unix:///path.sock); overrides the spec's "
                            "'store' field (default: the spec's, else "
                            "in-memory)")
    p_run.add_argument("--dry-run", action="store_true",
                       help="print the plan (incl. transfer candidates) and "
                            "exit without measuring anything")
    p_run.add_argument("--resume", action="store_true",
                       help="fold everything already recorded in the space "
                            "into each member's history before the first ask")
    p_run.add_argument("--out", default=None,
                       help="write the result summary JSON here")
    p_run.set_defaults(fn=_cmd_run)

    p_val = sub.add_parser("validate",
                           help="strict-parse a spec and print canonical JSON")
    p_val.add_argument("spec")
    p_val.set_defaults(fn=_cmd_validate)

    p_cat = sub.add_parser("catalog", help="list a store's registered spaces")
    p_cat.add_argument("--store", required=True,
                       help="store path or server URL")
    p_cat.set_defaults(fn=_cmd_catalog)

    p_fr = sub.add_parser(
        "frontier",
        help="print a space's measured Pareto frontier over properties")
    p_fr.add_argument("--store", required=True,
                      help="store path or server URL")
    p_fr.add_argument("--space", required=True, help="space id")
    p_fr.add_argument("--properties", required=True,
                      help="comma-separated measured property names")
    p_fr.add_argument("--modes", default=None,
                      help="comma-separated min|max per property "
                           "(default all min)")
    p_fr.set_defaults(fn=_cmd_frontier)

    p_rt = sub.add_parser(
        "record-trace",
        help="measure sampled configurations and capture a replayable "
             "actuation trace")
    p_rt.add_argument("spec", help="path to the spec JSON (its first "
                                   "experiment/connector is recorded)")
    p_rt.add_argument("--out", required=True,
                      help="trace JSONL output path")
    p_rt.add_argument("--n", type=int, default=50,
                      help="distinct configurations to sample (default 50)")
    p_rt.add_argument("--seed", type=int, default=0,
                      help="sampling seed (default 0)")
    p_rt.set_defaults(fn=_cmd_record_trace)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
