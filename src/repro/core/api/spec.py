"""The declarative Investigation specification (fully JSON round-trippable).

An :class:`InvestigationSpec` is the paper's "description of workload
configuration problems" made concrete: ONE document that names the space
(Ω), the methodology (A, via experiment factories), the optimizer fleet, the
execution backend, the budget/stopping rule, and the cross-space transfer
policy.  Every scenario the repo grew one-entrypoint-at-a-time — solo
ask/tell, pipelined ``max_inflight=N``, multi-optimizer campaigns, RSSC-style
transfer — is a *configuration* of this document, executed by
:class:`~repro.core.api.investigation.Investigation`.

Serialization contract
----------------------

* ``to_json()`` → plain-JSON dict; ``from_json()`` parses it back to an
  equal spec.  Parsing is STRICT: unknown fields raise ``ValueError`` at
  every nesting level (a typo'd knob must never silently no-op a paid
  cloud search), and ``schema_version`` must match :data:`SCHEMA_VERSION`.
* Experiments are code, so the spec stores *references*: a registry short
  name (see :func:`register_experiment`) or an ``"importable.module:attr"``
  path to a factory called with ``params``.
* Value mappings (``transfer.mappings``) are stored as pair LISTS, not JSON
  objects — JSON object keys are forcibly strings, which would corrupt
  numeric/boolean dimension values on a round trip.
"""

from __future__ import annotations

import importlib
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

from ..actions import Experiment
from ..connector.pricing import PricingModel, pricing_from_json
from ..connector.retry import RetryPolicy
from ..space import ProbabilitySpace

__all__ = ["SCHEMA_VERSION", "ExperimentSpec", "ConnectorSpec",
           "OptimizerSpec",
           "ExecutionSpec", "BudgetSpec", "TransferSpec", "ConstraintSpec",
           "ObjectiveSpec", "InvestigationSpec",
           "register_experiment", "resolve_experiment_factory",
           "EXPERIMENT_REGISTRY"]

#: Version of the spec JSON schema; from_json rejects any other value.
SCHEMA_VERSION = 1

_EXECUTION_BACKENDS = (None, "serial", "thread", "process", "queue")
_SELECTIONS = ("clustering", "top5", "linspace")

#: Short names for experiment factories usable in spec JSON (CLI-friendly).
EXPERIMENT_REGISTRY: dict = {}


def register_experiment(name: str, factory: Callable[..., Experiment]) -> None:
    """Register an experiment factory under a short name for spec JSON."""
    EXPERIMENT_REGISTRY[name] = factory


def resolve_experiment_factory(ref: str) -> Callable[..., Experiment]:
    """Resolve a spec's experiment reference: registry short name first
    (built-ins auto-load from :mod:`repro.core.api.workloads`), then an
    ``"module.path:attr"`` import."""
    if ref in EXPERIMENT_REGISTRY:
        return EXPERIMENT_REGISTRY[ref]
    if ":" not in ref:
        from . import workloads  # noqa: F401 — registers the built-ins
        if ref in EXPERIMENT_REGISTRY:
            return EXPERIMENT_REGISTRY[ref]
        raise ValueError(
            f"unknown experiment {ref!r}: not a registered name "
            f"({sorted(EXPERIMENT_REGISTRY)}) and not a 'module:attr' path")
    module_name, attr_path = ref.split(":", 1)
    obj: Any = importlib.import_module(module_name)
    for part in attr_path.split("."):
        obj = getattr(obj, part)
    return obj


def _reject_unknown(d: Mapping, allowed: Sequence[str], ctx: str) -> None:
    unknown = sorted(set(d) - set(allowed))
    if unknown:
        raise ValueError(
            f"{ctx}: unknown field(s) {unknown} (allowed: {sorted(allowed)})")


def _mappings_to_json(mappings: Mapping[str, Tuple]) -> dict:
    return {dim: [[s, t] for s, t in pairs]
            for dim, pairs in mappings.items()}


def _mappings_from_json(d: Any, ctx: str) -> dict:
    """Accept {dim: {src: tgt}} (convenient) or {dim: [[src, tgt], ...]}
    (round-trip canonical); normalize to {dim: ((src, tgt), ...)}."""
    if not isinstance(d, Mapping):
        raise ValueError(f"{ctx}: mappings must be an object, got {type(d)}")
    out: dict = {}
    for dim, m in d.items():
        if isinstance(m, Mapping):
            out[dim] = tuple((s, t) for s, t in m.items())
        else:
            out[dim] = tuple((pair[0], pair[1]) for pair in m)
    return out


@dataclass(frozen=True)
class ExperimentSpec:
    """One action-space entry: a factory reference + its parameters."""

    factory: str
    params: dict = field(default_factory=dict)

    def build(self) -> Experiment:
        exp = resolve_experiment_factory(self.factory)(**self.params)
        if not isinstance(exp, Experiment):
            raise TypeError(
                f"experiment factory {self.factory!r} returned "
                f"{type(exp).__name__}, not an Experiment")
        return exp

    def to_json(self) -> dict:
        return {"factory": self.factory, "params": dict(self.params)}

    @staticmethod
    def from_json(d: Mapping) -> "ExperimentSpec":
        _reject_unknown(d, ("factory", "params"), "experiment")
        if "factory" not in d:
            raise ValueError("experiment: 'factory' is required")
        return ExperimentSpec(factory=str(d["factory"]),
                              params=dict(d.get("params", {})))


#: Allowed keys of a connector spec's nested ``retry`` / ``pricing`` blocks
#: (strict like everything else in the document: a typo'd retry knob must
#: never silently leave a paid search un-retried).
_RETRY_FIELDS = ("provision_attempts", "run_attempts", "backoff_s",
                 "backoff_factor", "max_backoff_s", "jitter")
_PRICING_FIELDS = ("kind", "rate_per_s", "dimension", "rates", "default")


@dataclass(frozen=True)
class ConnectorSpec:
    """One action-space entry measured through the actuation lifecycle.

    The factory returns an
    :class:`~repro.core.connector.base.ExperimentConnector`, which is wrapped
    in a :class:`~repro.core.connector.lifecycle.LifecycleExperiment` with
    this entry's :class:`~repro.core.connector.retry.RetryPolicy` and
    :class:`~repro.core.connector.pricing.PricingModel`.  A factory may also
    return a ready :class:`~repro.core.actions.Experiment` (e.g. the
    ``trace-replay`` built-in, which already wraps itself) — then ``retry`` /
    ``pricing`` / ``virtual_clock`` must be unset here, because they would be
    silently ignored.

    ``virtual_clock=True`` drives the whole lifecycle — backoff sleeps and
    the connector itself, when it exposes a ``clock`` attribute — on a fresh
    :class:`~repro.core.clock.FakeClock`: zero real sleeps, virtual billing.
    That is the trace-replay default posture; live connectors keep real time.
    """

    factory: str
    params: dict = field(default_factory=dict)
    retry: Optional[RetryPolicy] = None
    pricing: Optional[PricingModel] = None
    virtual_clock: bool = False

    def build(self) -> Experiment:
        from ..clock import SYSTEM_CLOCK, FakeClock
        from ..connector import ExperimentConnector, LifecycleExperiment
        obj = resolve_experiment_factory(self.factory)(**self.params)
        if isinstance(obj, ExperimentConnector):
            clock = FakeClock() if self.virtual_clock else SYSTEM_CLOCK
            if self.virtual_clock and hasattr(obj, "clock"):
                obj.clock = clock  # replay sleeps on the same virtual time
            return LifecycleExperiment(obj, retry=self.retry,
                                       pricing=self.pricing, clock=clock)
        if isinstance(obj, Experiment):
            if (self.retry is not None or self.pricing is not None
                    or self.virtual_clock):
                raise ValueError(
                    f"connector factory {self.factory!r} returned a ready "
                    f"Experiment; retry/pricing/virtual_clock would be "
                    f"ignored — configure them through the factory's params")
            return obj
        raise TypeError(
            f"connector factory {self.factory!r} returned "
            f"{type(obj).__name__}, not an ExperimentConnector or Experiment")

    def to_json(self) -> dict:
        return {"factory": self.factory, "params": dict(self.params),
                "retry": None if self.retry is None else self.retry.to_json(),
                "pricing": None if self.pricing is None
                else self.pricing.to_json(),
                "virtual_clock": self.virtual_clock}

    @staticmethod
    def from_json(d: Mapping) -> "ConnectorSpec":
        _reject_unknown(d, ("factory", "params", "retry", "pricing",
                            "virtual_clock"), "connector")
        if "factory" not in d:
            raise ValueError("connector: 'factory' is required")
        retry = d.get("retry")
        if retry is not None:
            _reject_unknown(retry, _RETRY_FIELDS, "connector.retry")
        pricing = d.get("pricing")
        if pricing is not None:
            _reject_unknown(pricing, _PRICING_FIELDS, "connector.pricing")
        return ConnectorSpec(
            factory=str(d["factory"]),
            params=dict(d.get("params", {})),
            retry=None if retry is None else RetryPolicy.from_json(retry),
            pricing=None if pricing is None else pricing_from_json(pricing),
            virtual_clock=bool(d.get("virtual_clock", False)))


@dataclass(frozen=True)
class OptimizerSpec:
    """One fleet member: an optimizer family + seed (+ family kwargs).

    ``backend`` selects the ask-scoring implementation (``numpy`` — the
    reference — or the accelerated ``jax``/``pallas`` paths; see
    :mod:`repro.core.optimizers.accel`).  None defers to the family
    default, currently ``numpy``.  Validation is name-level only: an
    accelerator missing at build time degrades to numpy with a warning
    (resolve happens in the optimizer constructor), so one spec file runs
    on any install.
    """

    name: str
    seed: int = 0
    params: dict = field(default_factory=dict)
    backend: Optional[str] = None

    def __post_init__(self):
        from ..optimizers import OPTIMIZER_REGISTRY
        from ..optimizers.accel import BACKENDS
        if self.name not in OPTIMIZER_REGISTRY:
            raise ValueError(f"unknown optimizer {self.name!r} "
                             f"(known: {sorted(OPTIMIZER_REGISTRY)})")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown ask backend {self.backend!r} "
                             f"(known: {BACKENDS})")

    def build(self):
        from ..optimizers import OPTIMIZER_REGISTRY
        kwargs = dict(self.params)
        if self.backend is not None:
            kwargs["backend"] = self.backend
        return OPTIMIZER_REGISTRY[self.name](seed=self.seed, **kwargs)

    def to_json(self) -> dict:
        return {"name": self.name, "seed": self.seed,
                "params": dict(self.params), "backend": self.backend}

    @staticmethod
    def from_json(d: Mapping) -> "OptimizerSpec":
        _reject_unknown(d, ("name", "seed", "params", "backend"), "optimizer")
        if "name" not in d:
            raise ValueError("optimizer: 'name' is required")
        backend = d.get("backend")
        return OptimizerSpec(name=str(d["name"]), seed=int(d.get("seed", 0)),
                             params=dict(d.get("params", {})),
                             backend=None if backend is None else str(backend))


@dataclass(frozen=True)
class ExecutionSpec:
    """How experiments execute: backend routing + engine shape.

    ``max_inflight=None`` with ``batch_size=1`` is the classic serial loop;
    ``batch_size=N`` is the barriered batch engine; ``max_inflight=N`` is
    the pipelined engine (campaigns are always pipelined, one slot budget
    per member).  ``backend`` names an execution backend (``serial | thread
    | process | queue``) or None for the legacy workers-sized default.
    """

    backend: Optional[str] = None
    workers: int = 1
    max_inflight: Optional[int] = None
    batch_size: int = 1

    def __post_init__(self):
        if self.backend not in _EXECUTION_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(known: {_EXECUTION_BACKENDS})")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {self.max_inflight}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")

    def to_json(self) -> dict:
        return {"backend": self.backend, "workers": self.workers,
                "max_inflight": self.max_inflight,
                "batch_size": self.batch_size}

    @staticmethod
    def from_json(d: Mapping) -> "ExecutionSpec":
        _reject_unknown(d, ("backend", "workers", "max_inflight",
                            "batch_size"), "execution")
        mi = d.get("max_inflight")
        return ExecutionSpec(
            backend=d.get("backend"),
            workers=int(d.get("workers", 1)),
            max_inflight=None if mi is None else int(mi),
            batch_size=int(d.get("batch_size", 1)))


@dataclass(frozen=True)
class BudgetSpec:
    """Trial budget + the paper's §V-B1 stopping rule, per member."""

    max_trials: int = 50
    patience: int = 5
    min_trials: int = 1

    def __post_init__(self):
        if self.max_trials < 1:
            raise ValueError(f"max_trials must be >= 1, got {self.max_trials}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")

    def to_json(self) -> dict:
        return {"max_trials": self.max_trials, "patience": self.patience,
                "min_trials": self.min_trials}

    @staticmethod
    def from_json(d: Mapping) -> "BudgetSpec":
        _reject_unknown(d, ("max_trials", "patience", "min_trials"), "budget")
        return BudgetSpec(max_trials=int(d.get("max_trials", 50)),
                          patience=int(d.get("patience", 5)),
                          min_trials=int(d.get("min_trials", 1)))


@dataclass(frozen=True)
class TransferSpec:
    """Cross-space reuse policy (paper §IV-3/4): when enabled, the
    Investigation queries the :class:`~repro.core.api.catalog.SpaceCatalog`
    for related, already-measured spaces, measures a representative
    sub-space in the target, applies the transfer criteria, and — if they
    pass — warm-starts every member's history with surrogate predictions.

    ``sources`` restricts discovery to explicit space ids (empty = any
    related space); ``mappings`` are per-dimension source→target value-
    rename hints, stored as pair lists (``{dim: ((src, tgt), ...)}``);
    ``min_r``/``max_p`` are the paper's go/no-go criteria;
    ``max_representatives`` caps the paid representative measurements (the
    selected points are subsampled evenly over the value ranking, keeping
    the spread that pins the fit — the paper's clustering chose 4–33
    points, Table VI); ``max_warm`` caps the folded history
    (best-predicted first); ``seed`` fixes the representative-selection
    rng.

    ``predict_remaining`` is the RSSC step-⑧ sweep as a spec mode: after a
    transfer passes the criteria, build the predicted space ``A*_pred``
    (the fitted surrogate as a :class:`~repro.core.actions.
    SurrogateExperiment` over the target Ω) and sweep it over every
    still-unmeasured configuration, so the store holds a full predicted
    surface next to the paid measurements — queryable like any other
    space, provenance-marked ``predicted``.
    """

    enabled: bool = False
    sources: tuple = ()
    mappings: dict = field(default_factory=dict)
    min_r: float = 0.7
    max_p: float = 0.01
    selection: str = "clustering"
    max_representatives: Optional[int] = None
    max_warm: Optional[int] = None
    seed: int = 0
    predict_remaining: bool = False

    def __post_init__(self):
        if self.selection not in _SELECTIONS:
            raise ValueError(f"unknown selection {self.selection!r} "
                             f"(known: {_SELECTIONS})")

    def mapping_dicts(self) -> dict:
        """``{dim: {src: tgt}}`` view for translate()/find_related()."""
        return {dim: dict(pairs) for dim, pairs in self.mappings.items()}

    def to_json(self) -> dict:
        return {"enabled": self.enabled, "sources": list(self.sources),
                "mappings": _mappings_to_json(self.mappings),
                "min_r": self.min_r, "max_p": self.max_p,
                "selection": self.selection,
                "max_representatives": self.max_representatives,
                "max_warm": self.max_warm, "seed": self.seed,
                "predict_remaining": self.predict_remaining}

    @staticmethod
    def from_json(d: Mapping) -> "TransferSpec":
        _reject_unknown(d, ("enabled", "sources", "mappings", "min_r",
                            "max_p", "selection", "max_representatives",
                            "max_warm", "seed", "predict_remaining"),
                        "transfer")
        mw = d.get("max_warm")
        mr = d.get("max_representatives")
        return TransferSpec(
            enabled=bool(d.get("enabled", False)),
            sources=tuple(d.get("sources", ())),
            mappings=_mappings_from_json(d.get("mappings", {}), "transfer"),
            min_r=float(d.get("min_r", 0.7)),
            max_p=float(d.get("max_p", 0.01)),
            selection=str(d.get("selection", "clustering")),
            max_representatives=None if mr is None else int(mr),
            max_warm=None if mw is None else int(mw),
            seed=int(d.get("seed", 0)),
            predict_remaining=bool(d.get("predict_remaining", False)))


_CONSTRAINT_OPS = ("<=", ">=", "<", ">")


@dataclass(frozen=True)
class ConstraintSpec:
    """One hard SLA bound over a measured property (paper abstract: "minimal
    cost while meeting a defined service level agreement").

    A violating trial is *infeasible*, not failed: it was deployable, it was
    measured, and it is real evidence for the optimizers — it just must never
    be reported as an incumbent.  A missing or NaN property value is treated
    as infeasible: a sentinel must never silently pass an SLA.
    """

    property: str
    op: str
    bound: float

    def __post_init__(self):
        if not self.property:
            raise ValueError("constraint: 'property' is required")
        if self.op not in _CONSTRAINT_OPS:
            raise ValueError(f"constraint: unknown op {self.op!r} "
                             f"(known: {_CONSTRAINT_OPS})")
        object.__setattr__(self, "bound", float(self.bound))

    def satisfied(self, value: Optional[float]) -> bool:
        if value is None or value != value:  # missing or NaN: infeasible
            return False
        if self.op == "<=":
            return value <= self.bound
        if self.op == ">=":
            return value >= self.bound
        if self.op == "<":
            return value < self.bound
        return value > self.bound

    def describe(self) -> str:
        return f"{self.property} {self.op} {self.bound:g}"

    def to_json(self) -> dict:
        return {"property": self.property, "op": self.op, "bound": self.bound}

    @staticmethod
    def from_json(d: Mapping) -> "ConstraintSpec":
        _reject_unknown(d, ("property", "op", "bound"), "constraint")
        for req in ("property", "op", "bound"):
            if req not in d:
                raise ValueError(f"constraint: {req!r} is required")
        return ConstraintSpec(property=str(d["property"]), op=str(d["op"]),
                              bound=float(d["bound"]))


@dataclass(frozen=True)
class ObjectiveSpec:
    """What the search optimizes, beyond a single scalar property.

    Two independent extensions over the plain ``metric`` field:

    * **scalarization** — at most one of ``weights`` (a weighted sum of
      measured properties, ``((property, weight), ...)``) or ``ratio``
      (``(numerator, denominator)``, e.g. dollars per served request).
      Neither given means the investigation's ``metric`` is the objective.
    * **constraints** — hard SLA bounds; trials violating any are folded
      into histories as *infeasible* and excluded from incumbent selection,
      stopping-rule improvement, and reported bests.

    Direction still comes from the investigation's ``mode``.
    """

    weights: tuple = ()
    ratio: Optional[tuple] = None
    constraints: tuple = ()

    def __post_init__(self):
        if self.weights and self.ratio is not None:
            raise ValueError(
                "objective: give at most one of weights | ratio")
        weights = tuple((str(p), float(w)) for p, w in self.weights)
        if any(not p for p, _ in weights):
            raise ValueError("objective: weight property names are required")
        object.__setattr__(self, "weights", weights)
        if self.ratio is not None:
            if len(self.ratio) != 2 or not all(self.ratio):
                raise ValueError("objective: ratio must be "
                                 "[numerator, denominator]")
            object.__setattr__(
                self, "ratio", (str(self.ratio[0]), str(self.ratio[1])))
        constraints = tuple(self.constraints)
        for c in constraints:
            if not isinstance(c, ConstraintSpec):
                raise ValueError(f"objective: constraints must be "
                                 f"ConstraintSpec, got {type(c).__name__}")
        object.__setattr__(self, "constraints", constraints)

    @property
    def scalarized(self) -> bool:
        """True when the objective replaces the plain metric."""
        return bool(self.weights) or self.ratio is not None

    @property
    def label(self) -> str:
        """Display name of the scalarized objective ('' when not one)."""
        if self.weights:
            return "+".join(f"{w:g}*{p}" for p, w in self.weights)
        if self.ratio is not None:
            return f"{self.ratio[0]}/{self.ratio[1]}"
        return ""

    def objective_properties(self) -> tuple:
        """Properties the scalarization reads (empty = inherit metric)."""
        if self.weights:
            return tuple(p for p, _ in self.weights)
        if self.ratio is not None:
            return self.ratio
        return ()

    def constraint_properties(self) -> tuple:
        seen: dict = {}
        for c in self.constraints:
            seen.setdefault(c.property, None)
        return tuple(seen)

    def value(self, get: Callable[[str], float]) -> float:
        """Scalarized objective value; ``get`` maps property → value and
        may raise on a missing one (callers pre-check availability)."""
        if self.weights:
            return sum(w * float(get(p)) for p, w in self.weights)
        if self.ratio is not None:
            num = float(get(self.ratio[0]))
            den = float(get(self.ratio[1]))
            if den == 0.0:
                return float("inf") if num >= 0 else float("-inf")
            return num / den
        raise ValueError("objective is not scalarized; use the metric")

    def feasible(self, get: Callable[[str], Optional[float]]) -> bool:
        """``get`` returns None for a missing property (→ infeasible)."""
        return all(c.satisfied(get(c.property)) for c in self.constraints)

    def to_json(self) -> dict:
        return {"weights": [[p, w] for p, w in self.weights],
                "ratio": None if self.ratio is None else list(self.ratio),
                "constraints": [c.to_json() for c in self.constraints]}

    @staticmethod
    def from_json(d: Mapping) -> "ObjectiveSpec":
        _reject_unknown(d, ("weights", "ratio", "constraints"), "objective")
        ratio = d.get("ratio")
        return ObjectiveSpec(
            weights=tuple((pair[0], pair[1]) for pair in d.get("weights", ())),
            ratio=None if ratio is None else tuple(ratio),
            constraints=tuple(ConstraintSpec.from_json(c)
                              for c in d.get("constraints", ())))


@dataclass(frozen=True)
class InvestigationSpec:
    """The full declarative description of one configuration search.

    ``experiments`` may be empty ONLY when the Investigation is handed a
    ready :class:`~repro.core.discovery.DiscoverySpace` (the programmatic /
    legacy-shim path); a spec executed from JSON must name its experiments.
    ``share_history``/``warm_start`` carry the campaign semantics: fold
    other members' completions into every history / additionally fold
    records that predate the run.

    ``store`` selects the shared sample store the investigation rendezvouses
    through (paper §III-D): ``None`` keeps today's behavior (a private
    in-memory store, or whatever handle the caller passed in), a filesystem
    path opens/creates the SQLite reference backend, and a ``tcp://`` /
    ``unix://`` URL connects to a running
    ``python -m repro.core.store.server`` — resolved via
    :func:`repro.core.store.open_store`.  An explicit ``store=`` argument to
    :class:`~repro.core.api.investigation.Investigation` wins over the spec
    field (the caller's live handle is more specific than the document).
    """

    name: str
    space: ProbabilitySpace
    metric: str = ""
    experiments: tuple = ()
    connectors: tuple = ()
    mode: str = "min"
    optimizers: tuple = (OptimizerSpec("random"),)
    execution: ExecutionSpec = ExecutionSpec()
    budget: BudgetSpec = BudgetSpec()
    transfer: TransferSpec = TransferSpec()
    share_history: bool = True
    warm_start: bool = False
    store: Optional[str] = None
    objective: Optional[ObjectiveSpec] = None
    #: Free-form catalog annotations attached to the built Discovery Space's
    #: registration (e.g. a workload family's ``{"family": ..., "member":
    #: ...}`` identity block, see :mod:`repro.workloads`).  Must be plain
    #: JSON; never interpreted by the investigation itself.
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.mode not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {self.mode!r}")
        if not self.optimizers:
            raise ValueError("an investigation needs at least one optimizer")
        if len(self.optimizers) > 1 and self.execution.batch_size != 1:
            raise ValueError("multi-optimizer investigations are pipelined; "
                             "batch_size must be 1 (use max_inflight)")
        scalarized = self.objective is not None and self.objective.scalarized
        if not self.metric and not scalarized:
            raise ValueError("investigation: 'metric' is required "
                             "(or give a scalarized objective)")
        if self.metric and scalarized:
            raise ValueError("investigation: give either 'metric' or a "
                             "scalarized objective, not both")

    def objective_label(self) -> str:
        """The name of what the search minimizes/maximizes — the metric, or
        the scalarized objective's display label."""
        if self.objective is not None and self.objective.scalarized:
            return self.objective.label
        return self.metric

    # ------------------------------------------------------------- serialize

    def to_json(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "name": self.name,
            "space": self.space.to_json(),
            "experiments": [e.to_json() for e in self.experiments],
            "connectors": [c.to_json() for c in self.connectors],
            "metric": self.metric,
            "mode": self.mode,
            "optimizers": [o.to_json() for o in self.optimizers],
            "execution": self.execution.to_json(),
            "budget": self.budget.to_json(),
            "transfer": self.transfer.to_json(),
            "share_history": self.share_history,
            "warm_start": self.warm_start,
            "store": self.store,
            "objective": None if self.objective is None
            else self.objective.to_json(),
            "meta": dict(self.meta),
        }

    @staticmethod
    def from_json(d: Mapping) -> "InvestigationSpec":
        _reject_unknown(d, ("schema_version", "name", "space", "experiments",
                            "connectors", "metric", "mode", "optimizers",
                            "execution", "budget", "transfer",
                            "share_history", "warm_start", "store",
                            "objective", "meta"),
                        "investigation")
        version = d.get("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(f"unsupported schema_version {version!r} "
                             f"(this build reads {SCHEMA_VERSION})")
        for req in ("name", "space"):
            if req not in d:
                raise ValueError(f"investigation: {req!r} is required")
        objective = d.get("objective")
        return InvestigationSpec(
            name=str(d["name"]),
            space=ProbabilitySpace.from_json(d["space"]),
            metric=str(d.get("metric", "")),
            experiments=tuple(ExperimentSpec.from_json(e)
                              for e in d.get("experiments", ())),
            connectors=tuple(ConnectorSpec.from_json(c)
                             for c in d.get("connectors", ())),
            mode=str(d.get("mode", "min")),
            optimizers=tuple(OptimizerSpec.from_json(o)
                             for o in d.get("optimizers",
                                            ({"name": "random"},))),
            execution=ExecutionSpec.from_json(d.get("execution", {})),
            budget=BudgetSpec.from_json(d.get("budget", {})),
            transfer=TransferSpec.from_json(d.get("transfer", {})),
            share_history=bool(d.get("share_history", True)),
            warm_start=bool(d.get("warm_start", False)),
            store=None if d.get("store") is None else str(d["store"]),
            objective=None if objective is None
            else ObjectiveSpec.from_json(objective),
            meta=dict(d.get("meta", {})),
        )

    # --------------------------------------------------------------- file IO

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent, sort_keys=True)

    @staticmethod
    def loads(text: str) -> "InvestigationSpec":
        return InvestigationSpec.from_json(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps() + "\n")

    @staticmethod
    def load(path: str) -> "InvestigationSpec":
        with open(path) as f:
            return InvestigationSpec.loads(f.read())
