"""The Investigation: ONE engine behind every way this repo searches a space.

Four PRs of growth left four front doors — ``run_optimizer`` (solo
batched/pipelined ask/tell), ``Campaign`` (cooperative fleets), ``rssc_transfer``
(cross-space surrogates), and raw ``DiscoverySpace.sample_batch`` — exactly
the fragmentation the paper's formal problem description is meant to prevent.
:class:`Investigation` re-expresses them as *configurations* of one engine:

* a :class:`~repro.core.api.spec.InvestigationSpec` (declarative, JSON
  round-trippable) names the space, experiments, optimizer fleet, execution
  backend, budget, and transfer policy;
* :meth:`Investigation.plan` describes what would run — including which
  catalog spaces transfer could reuse — without paying for anything;
* :meth:`Investigation.run` executes: an optional §IV transfer stage
  (discover related measured spaces via the
  :class:`~repro.core.api.catalog.SpaceCatalog`, measure a representative
  sub-space, apply the r/p criteria, warm-start every member's history with
  surrogate predictions), then the search itself — the barriered batch loop
  for a solo ``batch_size`` run, or the
  :func:`~repro.core.campaign._drive_fleet` coordinator for pipelined and
  multi-optimizer runs;
* :meth:`Investigation.resume` re-enters a space whose store already holds
  history: everything recorded is folded into each member's model before the
  first ask, and re-proposals come back as free ``reused`` trials.

The legacy entrypoints are thin shims over this class —
``run_optimizer`` builds an Investigation from components and returns its
single member's run; ``Campaign.run`` hands its prebuilt members to one.
Their trajectories are regression-gated draw-for-draw, so the re-expression
is behaviour-preserving by test.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence

import numpy as np

from ..campaign import MemberResult, _drive_fleet, _Member
from ..clustering import select_indices
from ..discovery import DiscoverySpace
from ..execution import ExecutionBackend
from ..optimizers.base import (OptimizerRun, SearchAdapter, _StoppingRule,
                               as_scored)
from ..store import StoreBackend, open_store
from ..transfer import (PredictionQuality, TransferAssessment,
                        TransferCriteria, assess_transfer, prediction_quality)
from .catalog import SpaceCatalog
from .spec import InvestigationSpec, TransferSpec

__all__ = ["Investigation", "InvestigationPlan", "InvestigationResult",
           "TransferReport"]


@dataclass
class TransferReport:
    """What the §IV transfer stage found, measured, and folded."""

    applied: bool = False
    source_space_id: Optional[str] = None
    mapping: dict = field(default_factory=dict)
    assessment: Optional[TransferAssessment] = None
    n_source_samples: int = 0
    n_representatives: int = 0
    # paid work across EVERY candidate attempt, not just the one that
    # transferred: a rep pass that then failed the criteria still deployed
    # real experiments, and hiding that would bias warm-vs-cold comparisons
    n_rep_measured: int = 0
    n_rep_failed: int = 0
    n_warm_trials: int = 0       # entries folded into EACH member's history
    # the §IV-4 predict-remaining sweep (transfer.predict_remaining): how
    # many still-unmeasured configurations got surrogate predictions, and
    # the A*_pred space id they were recorded under (None = sweep not run)
    n_predicted: int = 0
    predicted_space_id: Optional[str] = None
    operation_id: Optional[str] = None
    #: digest -> surrogate-predicted value for warm entries that were NOT
    #: measured during the rep pass: the out-of-sample predictions that
    #: prediction-quality scoring pairs against later real measurements.
    warm_predictions: dict = field(default_factory=dict, repr=False)
    #: per-candidate outcome, in the order sources were tried
    attempts: list = field(default_factory=list)

    @property
    def paid(self) -> int:
        return self.n_rep_measured + self.n_rep_failed

    def summary(self) -> dict:
        out = {
            "applied": self.applied,
            "source_space_id": self.source_space_id,
            "n_source_samples": self.n_source_samples,
            "n_representatives": self.n_representatives,
            "rep_measurements_paid": self.paid,
            "warm_trials_per_member": self.n_warm_trials,
            "predicted": self.n_predicted,
            "predicted_space_id": self.predicted_space_id,
            "attempts": list(self.attempts),
        }
        if self.assessment is not None:
            out["criteria"] = self.assessment.summary()
        return out


@dataclass
class InvestigationPlan:
    """The dry-run answer: what :meth:`Investigation.run` would do."""

    name: str
    space_id: str
    engine: str                  # 'batched' | 'pipelined' | 'campaign'
    metric: str
    mode: str
    members: list                # labels, in fleet order
    backend: Optional[str]
    workers: int
    batch_size: int
    max_inflight: Optional[int]
    budget: dict
    share_history: bool
    warm_start: bool
    transfer_enabled: bool
    transfer_predict_remaining: bool = False
    transfer_candidates: list = field(default_factory=list)
    constraints: list = field(default_factory=list)  # SLA bound descriptions
    #: prior failed trials already recorded in the space, by lifecycle phase:
    #: ``{phase: {"count": n, "cost": charged}}`` (legacy rows → "unknown")
    failures: dict = field(default_factory=dict)

    def describe(self) -> str:
        objective = f"{self.mode} {self.metric}"
        if self.constraints:
            objective += "  s.t. " + ", ".join(self.constraints)
        lines = [
            f"investigation {self.name!r} on space {self.space_id[:12]}…",
            f"  objective : {objective}",
            f"  engine    : {self.engine} (backend="
            f"{self.backend or 'default'}, workers={self.workers}, "
            f"batch_size={self.batch_size}, max_inflight={self.max_inflight})",
            f"  members   : {', '.join(self.members)}",
            f"  budget    : max_trials={self.budget['max_trials']}/member, "
            f"patience={self.budget['patience']}, "
            f"min_trials={self.budget['min_trials']}",
            f"  sharing   : share_history={self.share_history}, "
            f"warm_start={self.warm_start}",
        ]
        if self.failures:
            parts = [f"{phase}={s['count']} (${s['cost']:.4g})"
                     for phase, s in sorted(self.failures.items())]
            lines.append(f"  failures  : {sum(s['count'] for s in self.failures.values())}"
                         f" prior failed trial(s) — {', '.join(parts)}")
        if not self.transfer_enabled:
            lines.append("  transfer  : disabled")
        elif not self.transfer_candidates:
            lines.append("  transfer  : enabled — no related measured space "
                         "in the catalog (search runs cold)")
        else:
            sweep = (" (+ predict-remaining sweep)"
                     if self.transfer_predict_remaining else "")
            lines.append(f"  transfer  : enabled{sweep} — "
                         f"{len(self.transfer_candidates)} candidate "
                         f"source(s):")
            for c in self.transfer_candidates:
                mapped = (f", renames {c['mapped_dimensions']}"
                          if c["mapped_dimensions"] else "")
                lines.append(f"    - {c['space_id'][:12]}… overlap="
                             f"{c['overlap']} measured={c['measured']}"
                             f"{mapped}")
        return "\n".join(lines)


@dataclass
class InvestigationResult:
    """Outcome of one :meth:`Investigation.run`."""

    name: str
    space_id: str
    metric: str
    mode: str
    engine: str
    members: List[MemberResult]
    #: ``(member_label, Trial)`` in tell order — the fleet event trace
    events: list = field(default_factory=list)
    transfer: Optional[TransferReport] = None
    #: failed trials in the space by lifecycle phase, with the provisioned
    #: cost they still charged: ``{phase: {"count": n, "cost": charged}}``.
    #: Rows that predate failure provenance surface as phase "unknown".
    failures: dict = field(default_factory=dict)

    @property
    def best(self):
        """Best *feasible* trial (SLA violators are real measurements but
        never incumbents; warm predictions never appear in events)."""
        sign = 1.0 if self.mode == "min" else -1.0
        valued = [t for _, t in self.events
                  if t.value is not None and t.feasible is not False]
        if not valued:
            return None
        return min(valued, key=lambda t: sign * t.value)

    @property
    def num_infeasible(self) -> int:
        return sum(1 for _, t in self.events if t.feasible is False)

    @property
    def num_trials(self) -> int:
        return len(self.events)

    @property
    def num_measured(self) -> int:
        return sum(1 for _, t in self.events if t.action == "measured")

    @property
    def paid_measurements(self) -> int:
        """Everything that cost a real deployment: measured + failed search
        trials, plus the transfer stage's representative measurements."""
        paid = sum(1 for _, t in self.events
                   if t.action in ("measured", "failed"))
        if self.transfer is not None:
            paid += self.transfer.paid
        return paid

    def prediction_quality(self) -> Optional[PredictionQuality]:
        """§V-B2 metrics of the transfer surrogate, scored OUT of sample:
        each warm prediction is paired with the real value the search later
        measured for the same configuration.  None when transfer was not
        applied or fewer than two predictions were ever verified.  The
        ``%savings`` field reports the §IV sampling-cost analogue — the
        fraction of the warm-covered target history that needed no real
        measurement."""
        if self.transfer is None or not self.transfer.applied:
            return None
        preds = self.transfer.warm_predictions
        pairs = {}
        for _, t in self.events:
            d = t.configuration.digest
            if t.value is not None and t.action == "measured" and d in preds:
                pairs[d] = (preds[d], t.value)  # last measurement wins
        if len(pairs) < 2:
            return None
        predicted = np.array([p for p, _ in pairs.values()])
        actual = np.array([a for _, a in pairs.values()])
        q = prediction_quality(predicted, actual, n_measured=0,
                               mode=self.mode)
        covered = self.transfer.n_warm_trials
        paid = self.transfer.paid
        savings = 1.0 - paid / max(covered + paid, 1)
        return replace(q, savings_pct=savings)

    def measurements_to_best(self) -> Optional[int]:
        """Paid measurements spent until the final best value first landed
        (transfer representative measurements included — they were paid)."""
        best = self.best
        if best is None:
            return None
        paid = self.transfer.paid if self.transfer is not None else 0
        for _, t in self.events:
            if t.action in ("measured", "failed"):
                paid += 1
            if t.value is not None and t.feasible is not False \
                    and t.value == best.value:
                return paid
        return paid  # pragma: no cover - best always appears in events

    def summary(self) -> dict:
        best = self.best
        q = self.prediction_quality()
        return {
            "name": self.name,
            "space_id": self.space_id,
            "engine": self.engine,
            "metric": self.metric,
            "mode": self.mode,
            "trials": self.num_trials,
            "measured": self.num_measured,
            "paid_measurements": self.paid_measurements,
            "infeasible": self.num_infeasible,
            "failures": {phase: dict(s)
                         for phase, s in sorted(self.failures.items())},
            "failed_cost": sum(s.get("cost", 0.0)
                               for s in self.failures.values()),
            "best": None if best is None else {
                "value": best.value,
                "configuration": best.configuration.as_dict(),
            },
            "members": [{
                "optimizer": m.optimizer,
                "operation_id": m.operation_id,
                "trials": m.run.num_trials,
                "measured": m.run.num_measured,
                "foreign_trials": m.foreign_trials,
                "warm_trials": m.warm_trials,
                "best": None if m.best is None else m.best.value,
            } for m in self.members],
            "transfer": None if self.transfer is None
            else self.transfer.summary(),
            "prediction_quality": None if q is None else q.summary(),
        }


class Investigation:
    """Declarative front door: build from a spec (or components), then
    ``plan()`` / ``run()`` / ``resume()``.

    Three construction paths share the engine:

    * ``Investigation(spec, store=...)`` — fully declarative: the Discovery
      Space is built from the spec's dimensions + experiment factories over
      the given store — or, when none is passed, over the backend the
      spec's ``store`` field names via
      :func:`repro.core.store.open_store` (a path opens SQLite, a
      ``tcp://``/``unix://`` URL connects to a store server; ``None`` means
      a fresh in-memory store);
    * ``Investigation(spec, ds=...)`` — programmatic space, declarative
      everything else (the spec's experiments may then be empty);
    * :meth:`from_components` / :meth:`for_members` — the legacy-shim paths
      used by ``run_optimizer`` and ``Campaign.run``.
    """

    def __init__(self, spec: InvestigationSpec,
                 store: Optional[StoreBackend] = None,
                 ds: Optional[DiscoverySpace] = None):
        self.spec = spec
        if ds is None:
            if not spec.experiments and not spec.connectors:
                raise ValueError(
                    "spec has no experiments; pass a ready DiscoverySpace "
                    "or add experiment/connector factories to the spec")
            from ..actions import ActionSpace
            built = [e.build() for e in spec.experiments] \
                + [c.build() for c in spec.connectors]
            ds = DiscoverySpace(
                space=spec.space,
                actions=ActionSpace.make(built),
                store=store if store is not None
                else open_store(spec.store or ":memory:"),
                meta=spec.meta or None)
        self.ds = ds
        # programmatic overrides (shim paths); None => build from the spec
        self._optimizers: Optional[list] = None
        self._rngs: Optional[list] = None
        self._members: Optional[list] = None
        self._backend = spec.execution.backend
        self._manage_history = True

    # ------------------------------------------------------------ shim paths

    @classmethod
    def from_components(cls, ds: DiscoverySpace, optimizers: Sequence,
                        metric: str, mode: str = "min",
                        rngs: Optional[Sequence] = None,
                        max_trials: int = 200, patience: int = 5,
                        min_trials: int = 1, batch_size: int = 1,
                        workers: int = 1, max_inflight: Optional[int] = None,
                        backend=None, share_history: bool = False,
                        warm_start: bool = False,
                        transfer: Optional[TransferSpec] = None,
                        objective=None,
                        name: str = "adhoc") -> "Investigation":
        """Build from prebuilt objects (optimizer instances, a ready space,
        possibly an ExecutionBackend instance) — the ``run_optimizer`` path.
        The spec's ``optimizers`` field stays declaratively empty-ish; the
        instances override it."""
        from .spec import BudgetSpec, ExecutionSpec
        spec = InvestigationSpec(
            name=name, space=ds.space, metric=metric, mode=mode,
            objective=objective,
            execution=ExecutionSpec(
                backend=backend if isinstance(backend, (str, type(None)))
                else None,
                workers=workers, max_inflight=max_inflight,
                batch_size=batch_size),
            budget=BudgetSpec(max_trials=max_trials, patience=patience,
                              min_trials=min_trials),
            transfer=transfer if transfer is not None else TransferSpec(),
            share_history=share_history, warm_start=warm_start)
        inv = cls(spec, ds=ds)
        inv._optimizers = list(optimizers)
        inv._rngs = list(rngs) if rngs is not None else None
        if isinstance(backend, ExecutionBackend):
            inv._backend = backend
        return inv

    @classmethod
    def for_members(cls, ds: DiscoverySpace, members: Sequence[_Member],
                    metric: str, mode: str, max_trials: int,
                    share_history: bool, backend,
                    name: str = "campaign") -> "Investigation":
        """Wrap prebuilt fleet members — the ``Campaign.run`` path.  The
        caller owns member construction, watermarks, and warm-start
        semantics; the Investigation only drives and reports."""
        from .spec import BudgetSpec, ExecutionSpec
        spec = InvestigationSpec(
            name=name, space=ds.space, metric=metric, mode=mode,
            execution=ExecutionSpec(
                backend=backend if isinstance(backend, (str, type(None)))
                else None,
                max_inflight=max(m.max_inflight for m in members)),
            budget=BudgetSpec(max_trials=max_trials),
            share_history=share_history)
        inv = cls(spec, ds=ds)
        inv._members = list(members)
        inv._manage_history = False
        if isinstance(backend, ExecutionBackend):
            inv._backend = backend
        return inv

    # -------------------------------------------------------------- planning

    @property
    def engine(self) -> str:
        n = len(self._members) if self._members is not None else (
            len(self._optimizers) if self._optimizers is not None
            else len(self.spec.optimizers))
        if n > 1:
            return "campaign"
        return "batched" if self.spec.execution.max_inflight is None \
            else "pipelined"

    def _member_labels(self) -> list:
        if self._members is not None:
            return [m.label for m in self._members]
        optimizers = (self._optimizers if self._optimizers is not None
                      else list(self.spec.optimizers))
        counts: dict = {}
        labels = []
        for opt in optimizers:
            n = counts.get(opt.name, 0)
            counts[opt.name] = n + 1
            labels.append(opt.name if n == 0 else f"{opt.name}#{n + 1}")
        return labels

    def plan(self) -> InvestigationPlan:
        """Describe the run without measuring anything: engine dispatch,
        fleet, budget, and — when transfer is enabled — the related spaces
        the catalog would offer as warm-start sources."""
        spec = self.spec
        candidates = []
        if spec.transfer.enabled:
            candidates = [rel.summary()
                          for rel in self._transfer_candidates()]
        return InvestigationPlan(
            name=spec.name, space_id=self.ds.space_id, engine=self.engine,
            metric=spec.objective_label(), mode=spec.mode,
            members=self._member_labels(),
            backend=(spec.execution.backend
                     if not isinstance(self._backend, ExecutionBackend)
                     else type(self._backend).__name__),
            workers=spec.execution.workers,
            batch_size=spec.execution.batch_size,
            max_inflight=spec.execution.max_inflight,
            budget=spec.budget.to_json(),
            share_history=spec.share_history, warm_start=spec.warm_start,
            transfer_enabled=spec.transfer.enabled,
            transfer_predict_remaining=spec.transfer.predict_remaining,
            transfer_candidates=candidates,
            constraints=[] if spec.objective is None else
            [c.describe() for c in spec.objective.constraints],
            failures=self._failure_summary())

    def _failure_summary(self) -> dict:
        """Per-phase failed-trial counts and charged provisioned cost for
        this space (``{phase: {"count", "cost"}}``) — best-effort: a store
        backend without failure provenance just reports nothing."""
        try:
            summary = self.ds.store.failure_summary(self.ds.space_id)
        except Exception:
            return {}
        return {str(phase): {"count": int(s["count"]),
                             "cost": float(s["cost"])}
                for phase, s in summary.items()}

    # ------------------------------------------------------------- execution

    def _build_members(self) -> list:
        spec = self.spec
        optimizers = (self._optimizers if self._optimizers is not None
                      else [o.build() for o in spec.optimizers])
        rngs = (self._rngs if self._rngs is not None
                else [np.random.default_rng(opt.seed) for opt in optimizers])
        if len(rngs) != len(optimizers):
            raise ValueError(f"rngs must match optimizers: "
                             f"{len(rngs)} != {len(optimizers)}")
        members = []
        for label, opt, rng in zip(self._member_labels(), optimizers, rngs):
            adapter = SearchAdapter(self.ds, spec.objective_label(),
                                    spec.mode, optimizer_name=label,
                                    objective=spec.objective)
            member = _Member(label, opt, adapter, rng, None,
                             spec.execution.max_inflight or 1)
            # the floor counts the member's OWN trials: warm-start and
            # foreign-folded history never satisfies a budget the caller
            # asked this member to spend itself
            member.rule = _StoppingRule(adapter, spec.budget.patience,
                                        spec.budget.min_trials,
                                        count=(lambda m=member: m.own_told))
            members.append(member)
        return members

    def run(self, resume: bool = False) -> InvestigationResult:
        """Execute the investigation (see class docstring for the stages).

        With ``resume=True`` (or ``spec.warm_start``), every sampling event
        already in the space's record is folded into each member's history
        before the first ask — the cross-session continuation path; reuse
        makes re-proposals free, so only new ground costs money.
        """
        spec = self.spec
        ds = self.ds
        members = (self._members if self._members is not None
                   else self._build_members())
        share = spec.share_history and (len(members) > 1
                                        or not self._manage_history)
        transfer_report: Optional[TransferReport] = None
        if self._manage_history:
            warm = resume or spec.warm_start
            if warm:
                for m in members:
                    m.adapter.record_watermark = 0
                    m.foreign_told += m.adapter.sync_foreign()
            if spec.transfer.enabled:
                transfer_report = self._apply_transfer(members)
            # fleet sharing starts at "now": pre-run records are covered by
            # the warm fold above (or deliberately invisible), and the
            # transfer stage's representative records are already in every
            # history as warm trials — advancing the watermark keeps them
            # from double-folding as foreign tells
            tail = ds.store.last_record_rowid(ds.space_id)
            for m in members:
                m.adapter.record_watermark = tail

        if self.engine == "batched":
            events, crash = self._run_batched(members[0])
        else:
            state = _drive_fleet(ds, members, spec.budget.max_trials,
                                 share_history=share, backend=self._backend)
            events, crash = state.events, state.crash
        if crash is not None:
            raise crash
        if share:
            # final fold so every member's reported history covers the
            # fleet's last completions (models queried post-run see the
            # full union)
            for m in members:
                m.foreign_told += m.adapter.sync_foreign()
        return InvestigationResult(
            name=spec.name, space_id=ds.space_id,
            metric=spec.objective_label(),
            mode=spec.mode, engine=self.engine,
            members=[self._member_result(m) for m in members],
            events=events, transfer=transfer_report,
            failures=self._failure_summary())

    def resume(self) -> InvestigationResult:
        """Continue an investigation whose store already holds history."""
        return self.run(resume=True)

    def _run_batched(self, member: _Member):
        """The barriered batch engine (the classic ``run_optimizer`` loop):
        each step asks for up to ``batch_size`` candidates and evaluates
        them with ``workers`` parallel experiment workers, telling the whole
        batch before the next ask.  With the defaults this is the serial
        suggest/evaluate loop, draw-for-draw."""
        from concurrent.futures import ThreadPoolExecutor

        spec = self.spec
        adapter, optimizer, rng, rule = (member.adapter, member.optimizer,
                                         member.rng, member.rule)
        batch_size = spec.execution.batch_size
        workers = spec.execution.workers
        backend = self._backend
        max_trials = spec.budget.max_trials
        events: list = []
        # one worker pool / backend for the whole run, not one per batch
        owned = not isinstance(backend, ExecutionBackend)
        pool = (ThreadPoolExecutor(max_workers=workers)
                if workers > 1 and backend is None else None)
        engine = (self.ds.execution_backend(backend, workers=workers)
                  if backend is not None else None)
        try:
            while not rule.stop and member.own_told < max_trials:
                n = min(batch_size, max_trials - member.own_told)
                batch = optimizer.ask(adapter, rng, n=n)
                if not as_scored(batch):
                    member.exhausted = True
                    break
                before = len(adapter.trials)
                adapter.evaluate_batch(batch, workers=workers,
                                       executor=pool, backend=engine)
                told = adapter.trials[before:]
                member.own_told += len(told)
                for t in told:
                    rule.observe(t.value, t.feasible)
                    events.append((member.label, t))
        finally:
            if pool is not None:
                pool.shutdown(wait=False)
            if engine is not None and owned:
                engine.close()
        return events, None

    def frontier(self, properties: Sequence[str],
                 modes: Optional[Sequence[str]] = None) -> list:
        """The space's measured Pareto frontier over ``properties`` —
        ``[(configuration, values), ...]`` straight from the store backend
        (:meth:`~repro.core.store.base.StoreBackend.frontier`), restricted
        to this investigation's action-space provenance."""
        return self.ds.store.frontier(
            self.ds.space_id, properties, modes,
            list(self.ds.actions.identifiers))

    def _member_result(self, member: _Member) -> MemberResult:
        spec = self.spec
        run = OptimizerRun(
            optimizer=member.label, metric=spec.objective_label(),
            mode=spec.mode,
            trials=member.own_trials(),
            operation_id=member.adapter.operation_id,
            batch_size=(spec.execution.batch_size
                        if self.engine == "batched" else 1),
            max_inflight=(None if self.engine == "batched"
                          else member.max_inflight))
        return MemberResult(
            optimizer=member.label,
            operation_id=member.adapter.operation_id,
            run=run, foreign_trials=member.foreign_told,
            history_size=len(member.adapter.trials),
            warm_trials=member.adapter.warm_told)

    # -------------------------------------------------------------- transfer

    def _transfer_candidates(self) -> list:
        spec = self.spec
        catalog = SpaceCatalog(self.ds.store)
        candidates = catalog.find_related(
            self.ds.space, exclude=[self.ds.space_id],
            mappings=spec.transfer.mapping_dicts(), min_overlap=1.0,
            metric=spec.metric, min_measured=3)
        if spec.transfer.sources:
            allowed = set(spec.transfer.sources)
            candidates = [c for c in candidates
                          if c.entry.space_id in allowed]
        return candidates

    def _apply_transfer(self, members: list) -> TransferReport:
        """The §IV RSSC procedure, automated end to end: discover a related
        measured space in the catalog, measure its representative sub-space
        here, apply the transfer criteria, and (on pass) warm-start every
        member with surrogate predictions over the source's full history.
        Candidates are tried best-related-first until one transfers; a run
        where none does reports the attempts and searches cold."""
        spec = self.spec
        t = spec.transfer
        ds = self.ds
        catalog = SpaceCatalog(ds.store)
        report = TransferReport()
        rng = np.random.default_rng(t.seed)
        sign = 1.0 if spec.mode == "min" else -1.0
        for rel in self._transfer_candidates():
            pairs = catalog.measured_pairs(rel.entry, spec.metric)
            if len(pairs) < 3:
                report.attempts.append(
                    {"space_id": rel.entry.space_id,
                     "outcome": "skipped: <3 measured source samples"})
                continue
            values = np.array([v for _, v in pairs])
            idx = select_indices(values, t.selection, rng)
            if t.max_representatives is not None \
                    and len(idx) > t.max_representatives:
                # budget the paid rep pass: keep points evenly spaced over
                # the value ranking so the extremes that pin the linear
                # fit's slope survive (deterministic)
                order = sorted(idx, key=lambda i: (values[i], i))
                keep = np.linspace(0, len(order) - 1,
                                   num=t.max_representatives)
                idx = sorted({order[int(round(k))] for k in keep})
            rep_pairs = [pairs[i] for i in idx]
            translated = [rel.entry.space.translate(c, rel.mapping)
                          for c, _ in rep_pairs]
            op = ds.begin_operation("transfer", {
                "source_space": rel.entry.space_id,
                "metric": spec.metric, "selection": t.selection,
                "mapping": {d: sorted(m.items()) for d, m in
                            rel.mapping.items()} if rel.mapping else {}})
            results = ds.sample_batch(translated, operation_id=op)
            kept_src, kept_tgt = [], []
            measured_values: dict = {}
            failed_digests: set = set()
            n_meas = n_fail = 0
            for (src_c, src_v), tgt_c, r in zip(rep_pairs, translated,
                                                results):
                if r.action == "measured":
                    n_meas += 1
                elif r.action == "failed":
                    n_fail += 1
                if not r.ok:
                    failed_digests.add(tgt_c.digest)
                    continue
                if not r.sample.has(spec.metric):
                    continue
                tgt_v = float(r.sample.value(spec.metric))
                kept_src.append(src_v)
                kept_tgt.append(tgt_v)
                measured_values[tgt_c.digest] = tgt_v
            # every attempt's rep pass deployed real experiments — charge
            # them even when the criteria then reject the candidate
            report.n_rep_measured += n_meas
            report.n_rep_failed += n_fail
            assessment = assess_transfer(
                kept_src, kept_tgt, TransferCriteria(t.min_r, t.max_p))
            report.attempts.append({
                "space_id": rel.entry.space_id,
                "outcome": "transfer" if assessment.transferable
                else "criteria not met",
                "rep_paid": n_meas + n_fail,
                **assessment.summary()})
            if not assessment.transferable:
                continue
            surrogate = assessment.surrogate
            warm, predictions = [], {}
            for src_c, src_v in pairs:
                tgt_c = rel.entry.space.translate(src_c, rel.mapping)
                digest = tgt_c.digest
                if digest in failed_digests:
                    # the rep pass just OBSERVED this configuration fail in
                    # the target: a plausible surrogate value would steer
                    # every member toward a known-infeasible point
                    continue
                if digest in measured_values:
                    warm.append((tgt_c, measured_values[digest]))
                else:
                    pred = float(surrogate(src_v))
                    predictions[digest] = pred
                    warm.append((tgt_c, pred))
            if t.max_warm is not None and len(warm) > t.max_warm:
                # deterministic truncation, best-predicted first: the most
                # informative region of the source survives the cap
                warm.sort(key=lambda cv: (sign * cv[1], cv[0].digest))
                warm = warm[:t.max_warm]
                kept = {c.digest for c, _ in warm}
                predictions = {d: v for d, v in predictions.items()
                               if d in kept}
            for m in members:
                m.adapter.warm_start(warm)
            report.applied = True
            report.source_space_id = rel.entry.space_id
            report.mapping = rel.mapping
            report.assessment = assessment
            report.n_source_samples = len(pairs)
            report.n_representatives = len(rep_pairs)
            report.n_warm_trials = len(warm)
            report.operation_id = op
            report.warm_predictions = predictions
            if t.predict_remaining and ds.space.finite:
                self._predict_remaining(report, rel, pairs, assessment, op)
            return report
        return report

    def _predict_remaining(self, report: TransferReport, rel, pairs,
                           assessment, fit_op: str) -> None:
        """The RSSC step-⑧ sweep as a spec mode (``transfer.
        predict_remaining``): build ``A*_pred`` — this space plus a
        :class:`~repro.core.actions.SurrogateExperiment` wrapping the fitted
        line over the source's measured values — and sweep it over every
        configuration the search has not touched, so the store ends up
        holding a full predicted surface (provenance-marked ``predicted``)
        next to the paid measurements.  A target point whose source sibling
        was never measured fails its prediction (terminal, recorded), same
        as the serial RSSC sweep."""
        from ..actions import MeasurementError, SurrogateExperiment

        spec = self.spec
        src_values = {rel.entry.space.translate(c, rel.mapping).digest:
                      float(v) for c, v in pairs}

        def lookup(target_config):
            digest = target_config.digest
            if digest not in src_values:
                raise MeasurementError(
                    f"no source value of {spec.metric!r} for "
                    f"{target_config!r}")
            return src_values[digest]

        surrogate = SurrogateExperiment(
            source=lookup,
            model=assessment.surrogate,
            property_name=spec.metric,
            name=f"transfer-{spec.metric}",
            version="1",
            params={"slope": assessment.surrogate.slope,
                    "intercept": assessment.surrogate.intercept,
                    "source_space": rel.entry.space_id,
                    "fit_op": fit_op})
        predicted_space = self.ds.with_predictor(surrogate)
        pred_op = predicted_space.begin_operation("transfer-predict")
        results = predicted_space.sample_batch(
            list(predicted_space.remaining_configurations()),
            operation_id=pred_op)
        report.n_predicted = sum(1 for r in results
                                 if r.action == "predicted")
        report.predicted_space_id = predicted_space.space_id
