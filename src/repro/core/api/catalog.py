"""The persistent SpaceCatalog: what has already been measured, and how it
relates to what you want to measure next.

The paper's reuse story (§IV, §V-B) assumes an investigator can *find* the
previously-measured space worth transferring from.  The catalog is that
lookup: it reads the store's ``spaces`` table (every
:class:`~repro.core.discovery.DiscoverySpace` ever constructed over the
store registers itself with its Ω digest + entity metadata) joined with
per-space sampling-record counts, and answers relatedness queries:

* **exact** — another study over the same dimensions (typically a different
  action space: new model architecture, new cloud provider — the paper's
  FT-TRANS pattern);
* **renamed values** — dimensions match by name/kind but some finite values
  were renamed (``gpu_model: A100-PCIE → A100-SXM4`` — the §IV-1
  ``map_values`` pattern), connected through an explicit caller mapping or,
  for same-cardinality categorical dimensions, a positionally *inferred*
  one (flagged, and ranked below explicit matches);
* **disjoint** — nothing to transfer; filtered out by ``min_overlap``.

``find_related`` is deliberately read-only and cheap (two queries + pure
matching) so ``Investigation.plan()`` can call it in a dry run without
paying for anything.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

from ..space import ProbabilitySpace
from ..store import StoreBackend

__all__ = ["CatalogEntry", "RelatedSpace", "SpaceCatalog"]


@dataclass(frozen=True)
class CatalogEntry:
    """One registered Discovery Space + its measurement statistics."""

    space_id: str
    space: ProbabilitySpace
    action_ids: tuple
    space_digest: str
    meta: dict
    created_at: float
    n_records: int = 0
    n_measured: int = 0
    n_failed: int = 0
    n_distinct: int = 0

    @property
    def properties(self) -> tuple:
        """Observed property names, when the registering build recorded them
        (empty for pre-catalog rows — treat as unknown, not as none)."""
        return tuple(self.meta.get("properties", ()))

    @property
    def family(self) -> dict:
        """The workload-family identity block the registering space attached
        (see :mod:`repro.workloads`), empty for family-less spaces.  Two
        entries with equal family blocks are siblings: the same generator
        with different member knobs (sequence length, topology)."""
        return dict(self.meta.get("family", {}))

    def summary(self) -> dict:
        return {
            "space_id": self.space_id,
            "dimensions": list(self.space.names),
            "size": self.space.size if self.space.finite else None,
            "properties": list(self.properties),
            "records": self.n_records,
            "measured": self.n_measured,
            "distinct": self.n_distinct,
        }


@dataclass(frozen=True)
class RelatedSpace:
    """A catalog entry related to a query space, with how to reach it.

    ``mapping`` is the per-dimension source→target value rename needed to
    translate the entry's configurations into the query space (empty for an
    exact dimension match); ``inferred_dims`` names dimensions whose mapping
    was positionally inferred rather than caller-supplied.
    """

    entry: CatalogEntry
    overlap: float
    shared_dimensions: tuple
    mapping: dict = field(default_factory=dict)
    inferred_dims: tuple = ()

    @property
    def exact(self) -> bool:
        return self.overlap == 1.0 and not self.mapping

    def summary(self) -> dict:
        return {
            "space_id": self.entry.space_id,
            "overlap": round(self.overlap, 3),
            "shared_dimensions": list(self.shared_dimensions),
            "mapped_dimensions": sorted(self.mapping),
            "inferred_dimensions": list(self.inferred_dims),
            "measured": self.entry.n_measured,
        }


def _match_dimension(src_dim, tgt_dim, explicit: Optional[Mapping]):
    """(mapping, inferred) when the dimensions are relatable, else None.

    ``mapping`` is the src→tgt value rename restricted to values that
    actually change (empty = identical value sets)."""
    if src_dim.kind != tgt_dim.kind:
        return None
    if src_dim.kind == "continuous":
        if (src_dim.low, src_dim.high) == (tgt_dim.low, tgt_dim.high):
            return {}, False
        return None
    if src_dim.values == tgt_dim.values:
        return {}, False
    if src_dim.kind == "categorical" \
            and set(src_dim.values) == set(tgt_dim.values):
        # same unordered value set declared in a different order: identity —
        # positional inference here would cross-rename identical values
        return {}, False
    if explicit is not None:
        mapped = tuple(explicit.get(v, v) for v in src_dim.values)
        if (len(mapped) == len(set(mapped))
                and set(mapped) == set(tgt_dim.values)):
            return ({v: explicit[v] for v in src_dim.values
                     if v in explicit and explicit[v] != v}, False)
        return None
    if (src_dim.kind == "categorical"
            and len(src_dim.values) == len(tgt_dim.values)):
        # positional inference: a pure rename of an unordered finite set —
        # the stored value order carries the correspondence.  Never done for
        # discrete numeric dimensions, whose values are quantities (a space
        # with mem_gb [1,2,4] is NOT a renaming of one with [8,16,32]).
        return ({s: t for s, t in zip(src_dim.values, tgt_dim.values)
                 if s != t}, True)
    return None


class SpaceCatalog:
    """Query interface over every space registered in a sample store."""

    def __init__(self, store: StoreBackend):
        self.store = store

    # -------------------------------------------------------------- listing

    def entries(self) -> list:
        """All registered spaces, oldest first, with record counts."""
        stats = self.store.space_stats()
        out = []
        for row in self.store.list_spaces():
            s = stats.get(row["space_id"], {})
            out.append(CatalogEntry(
                space_id=row["space_id"],
                space=ProbabilitySpace.from_json(row["space_json"]),
                action_ids=tuple(row["actions"]),
                space_digest=row["space_digest"],
                meta=row["meta"],
                created_at=row["created_at"],
                n_records=s.get("records", 0),
                n_measured=s.get("measured", 0),
                n_failed=s.get("failed", 0),
                n_distinct=s.get("distinct", 0),
            ))
        return out

    def get(self, space_id: str) -> Optional[CatalogEntry]:
        for entry in self.entries():
            if entry.space_id == space_id:
                return entry
        return None

    # ----------------------------------------------------------- relatedness

    def find_related(
        self,
        space: ProbabilitySpace,
        exclude: Sequence[str] = (),
        mappings: Optional[Mapping[str, Mapping]] = None,
        min_overlap: float = 1.0,
        metric: Optional[str] = None,
        min_measured: int = 0,
        family: Optional[Mapping] = None,
    ) -> list:
        """Catalog entries relatable to ``space``, best candidates first.

        ``overlap`` is matched dimensions over the *union* of dimension
        names, so extra dimensions on either side dilute it — two spaces
        with disjoint dimensions score 0 and never match.  ``mappings``
        supplies explicit per-dimension src→tgt value renames
        (``{dim: {src: tgt}}``); without one, a same-cardinality
        categorical rename is positionally inferred and flagged.

        ``exclude`` drops space ids (callers pass their own); ``metric``
        keeps only entries whose registered properties include it (entries
        with unknown properties pass — the data check happens when values
        are read); ``min_measured`` requires that many measured records;
        ``family`` keeps only entries whose registered family block equals
        it — restricting transfer sources to siblings of one workload
        family (dimension matching alone can relate e.g. two different
        models that happen to share knob names).

        Ranking: exact matches first, then by overlap, then by measured
        data volume, explicit mappings before inferred ones.
        """
        mappings = mappings or {}
        excluded = set(exclude)
        out = []
        for entry in self.entries():
            if entry.space_id in excluded:
                continue
            if entry.n_measured < min_measured:
                continue
            if metric is not None and entry.properties \
                    and metric not in entry.properties:
                continue
            if family is not None and entry.family != dict(family):
                continue
            src_dims = {d.name: d for d in entry.space.dimensions}
            tgt_dims = {d.name: d for d in space.dimensions}
            union = set(src_dims) | set(tgt_dims)
            matched, mapping, inferred = [], {}, []
            for name in sorted(set(src_dims) & set(tgt_dims)):
                m = _match_dimension(src_dims[name], tgt_dims[name],
                                     mappings.get(name))
                if m is None:
                    continue
                dim_map, was_inferred = m
                matched.append(name)
                if dim_map:
                    mapping[name] = dim_map
                if was_inferred:
                    inferred.append(name)
            overlap = len(matched) / len(union) if union else 0.0
            if overlap < min_overlap or not matched:
                continue
            out.append(RelatedSpace(
                entry=entry, overlap=overlap,
                shared_dimensions=tuple(matched),
                mapping=mapping, inferred_dims=tuple(inferred)))
        out.sort(key=lambda r: (not r.exact, -r.overlap, -r.entry.n_measured,
                                len(r.inferred_dims), r.entry.space_id))
        return out

    # ------------------------------------------------------------ source data

    def measured_pairs(self, entry: CatalogEntry, metric: str) -> list:
        """``[(configuration, value), ...]`` of the entry's *measured* (not
        predicted) values for ``metric``, in first-sampled order (last
        measured write wins per configuration) — the source data a transfer
        surrogate is fitted on.  Reads raw store rows in one JOIN scan
        (:meth:`SampleStore.measured_property_values`): the source space's
        experiments are code and need not be reconstructible here.
        """
        return self.store.measured_property_values(
            entry.space_id, metric, list(entry.action_ids))

    def frontier(self, entry: CatalogEntry, properties: Sequence[str],
                 modes: Optional[Sequence[str]] = None) -> list:
        """The entry's measured Pareto frontier over ``properties`` —
        ``[(configuration, values), ...]`` via the store backend's
        :meth:`~repro.core.store.base.StoreBackend.frontier` view,
        provenance-restricted to the entry's registered action space.  The
        multi-objective analogue of :meth:`measured_pairs`: what an
        SLA-aware investigation inspects before deciding whether a related
        space already covers its cost/latency trade-off."""
        return self.store.frontier(entry.space_id, properties, modes,
                                   list(entry.action_ids))
