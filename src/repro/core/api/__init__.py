"""repro.core.api — the declarative Investigation front door.

ONE way in for every search scenario the repo supports: describe the study
as an :class:`InvestigationSpec` (space + experiments + optimizer fleet +
execution + budget + transfer policy, JSON round-trippable), hand it to an
:class:`Investigation`, and ``plan()`` / ``run()`` / ``resume()``.  Solo
ask/tell, batched, pipelined, multi-optimizer campaigns, and RSSC-style
cross-space transfer are all *configurations* of this one engine — the
legacy entrypoints (``run_optimizer``, ``Campaign.run``) are thin shims
over it, draw-for-draw.

The :class:`SpaceCatalog` is the persistent reuse index: every Discovery
Space registers itself (Ω digest + entity metadata + record counts) in the
shared store, and ``Investigation.run()`` with ``transfer.enabled`` queries
it for related, already-measured spaces to warm-start from — the paper's
>90 % configuration-search speed-up path (§IV-3/4, §V-B), reproduced by
``python -m benchmarks.transfer_bench``.

Spec-driven CLI::

    python -m repro.core.api run spec.json --store study.db [--dry-run]
    python -m repro.core.api catalog --store study.db
"""

from .catalog import CatalogEntry, RelatedSpace, SpaceCatalog
from .investigation import (Investigation, InvestigationPlan,
                            InvestigationResult, TransferReport)
from .spec import (SCHEMA_VERSION, BudgetSpec, ConstraintSpec, ExecutionSpec,
                   ExperimentSpec, InvestigationSpec, ObjectiveSpec,
                   OptimizerSpec, TransferSpec, register_experiment,
                   resolve_experiment_factory)
from . import workloads  # noqa: F401 — registers the built-in factories

__all__ = [
    "Investigation", "InvestigationPlan", "InvestigationResult",
    "TransferReport", "InvestigationSpec", "ExperimentSpec", "OptimizerSpec",
    "ExecutionSpec", "BudgetSpec", "TransferSpec", "ConstraintSpec",
    "ObjectiveSpec", "SCHEMA_VERSION",
    "SpaceCatalog", "CatalogEntry", "RelatedSpace", "register_experiment",
    "resolve_experiment_factory",
]
