"""Built-in experiment factories usable by name in spec JSON.

Experiments are code, so an :class:`~repro.core.api.spec.InvestigationSpec`
references them by factory — either an ``"importable.module:attr"`` path or
one of the short names registered here.  These built-ins are small synthetic
cloud-configuration surfaces (closed-form, instant) used by the CLI smoke
specs, the examples, and the transfer bench; real deployments register their
own factories via :func:`~repro.core.api.spec.register_experiment` or ship a
module path.

``linear_shift`` wraps another factory's experiment in an affine transform
(+ deterministic per-configuration jitter) — the canonical "related space"
(new provider / new hardware generation, same shape) the transfer machinery
exists for.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

from ..actions import Experiment, FunctionExperiment
from ..entities import Configuration, content_hash
from .spec import register_experiment, resolve_experiment_factory

__all__ = ["quad", "cloud_deploy", "cloud_sla", "linear_shift",
           "trace_replay", "llm_dryrun", "llm_walltime"]


def quad(x_dim: str = "x", y_dim: str = "y", prop: str = "loss") -> Experiment:
    """A 2-d quadratic bowl: min at (0.5, -0.5).  Test/smoke surface."""

    def fn(c: Configuration):
        return {prop: (c[x_dim] - 0.5) ** 2 + (c[y_dim] + 0.5) ** 2}

    return FunctionExperiment(fn=fn, properties=(prop,), name="quad",
                              params={"x": x_dim, "y": y_dim, "prop": prop})


def cloud_deploy(prop: str = "cost_per_1k") -> Experiment:
    """Synthetic cloud-deployment cost surface (instance × workers ×
    batch_size × prefetch) — the cooperative-campaign example's workload,
    exposed as a named factory for spec JSON."""
    rate = {"m5.large": 90.0, "m5.xlarge": 170.0,
            "c5.xlarge": 210.0, "c5.2xlarge": 400.0}
    price = {"m5.large": 0.096, "m5.xlarge": 0.192,
             "c5.xlarge": 0.17, "c5.2xlarge": 0.34}

    def fn(c: Configuration):
        eff = min(1.0, 0.4 + 0.13 * np.log2(c["workers"] * c["batch_size"] / 8))
        eff *= 1.0 + 0.05 * np.log2(c["prefetch"])
        throughput = rate[c["instance"]] * c["workers"] * eff
        return {prop: 1000.0 * price[c["instance"]] * c["workers"]
                / (3.6 * throughput)}

    return FunctionExperiment(fn=fn, properties=(prop,), name="cloud-deploy",
                              params={"prop": prop})


def cloud_sla(cost_prop: str = "cost_per_1k",
              latency_prop: str = "p95_ms") -> Experiment:
    """The :func:`cloud_deploy` surface with a p95-latency property next to
    the cost — the SLA-constrained example's workload (paper abstract:
    minimal cost while meeting a defined service level agreement).

    Latency falls with per-worker batch efficiency and the instance's
    compute tier, while cost favors small, slow deployments — so the
    cheapest configurations violate any reasonable latency bound and a
    cost-only search is actively steered toward SLA violators.  Used by
    ``examples/specs/sla_constrained.json``.
    """
    inner = cloud_deploy(prop=cost_prop)
    tier = {"m5.large": 1.0, "m5.xlarge": 0.72,
            "c5.xlarge": 0.55, "c5.2xlarge": 0.38}

    def fn(c: Configuration):
        out = dict(inner.measure(c))
        eff = min(1.0, 0.4 + 0.13 * np.log2(c["workers"] * c["batch_size"] / 8))
        queue = 1.0 + 4.0 / (c["workers"] * eff)
        out[latency_prop] = 120.0 * tier[c["instance"]] * queue \
            / (1.0 + 0.1 * np.log2(c["prefetch"]))
        return out

    return FunctionExperiment(
        fn=fn, properties=(cost_prop, latency_prop), name="cloud-sla",
        params={"cost": cost_prop, "latency": latency_prop})


def linear_shift(base: str, scale: float = 1.2, offset: float = 10.0,
                 noise: float = 0.0, seed: int = 0, name: str = "shifted",
                 rename: dict = None, **base_params) -> Experiment:
    """An affine transform of another factory's surface — a related space's
    experiment (e.g. the same workload on a newer hardware generation).

    ``rename`` maps THIS space's dimension values back to the base
    experiment's (the inverse of the §IV-1 ``map_values`` rename), so a
    renamed-value target space can still be evaluated through the source
    surface.  ``noise`` adds deterministic per-configuration jitter keyed on
    the configuration digest, so the relationship is strong-but-not-exact.
    """
    inner = resolve_experiment_factory(base)(**base_params)
    rename = rename or {}

    def fn(c: Configuration):
        values = c.as_dict()
        for dim, m in rename.items():
            if dim in values:
                values[dim] = m.get(values[dim], values[dim])
        out = inner.measure(Configuration.make(values))
        jitter = 0.0
        if noise:
            h = int(content_hash([seed, c.digest])[:8], 16)
            jitter = noise * (2.0 * (h / 0xFFFFFFFF) - 1.0)
        return {k: scale * v + offset + jitter for k, v in out.items()}

    return FunctionExperiment(
        fn=fn, properties=tuple(inner.observed_properties), name=name,
        # the FULL parameterization: rename and the base factory's kwargs
        # change the measured surface, so they must change the experiment
        # identity too — stored provenance is keyed on it (hermetic
        # (name, version, params) contract), and two different surfaces
        # sharing an identifier would let the catalog attribute one
        # space's values to the other
        params={"base": base, "scale": scale, "offset": offset,
                "noise": noise, "seed": seed,
                "rename": sorted((dim, sorted(m.items()))
                                 for dim, m in rename.items()),
                "base_params": sorted(base_params.items())})


def trace_replay(path: str, retry=None, pricing=None,
                 virtual_clock: bool = True) -> Experiment:
    """Replay a recorded actuation trace (see
    :mod:`repro.core.connector.trace`) as a live experiment: every recorded
    provisioning failure, retry sequence, duration, and parsed property is
    re-enacted — zero cloud spend.

    ``retry``/``pricing`` accept JSON blocks (spec-friendly) or constructed
    policy/model objects; when omitted they default to the blocks the trace
    was *captured* under (from its header), so a bare
    ``{"factory": "trace-replay", "params": {"path": ...}}`` reproduces the
    recording's behavior — including its charged costs.  ``virtual_clock``
    (the default) replays on a fresh :class:`~repro.core.clock.FakeClock`,
    advancing virtual time instead of sleeping; pass False to re-enact the
    recording in real time.
    """
    from ..clock import SYSTEM_CLOCK, FakeClock
    from ..connector import (LifecycleExperiment, RetryPolicy, TraceConnector,
                             pricing_from_json)
    clock = FakeClock() if virtual_clock else SYSTEM_CLOCK
    connector = TraceConnector(path, clock=clock)
    header = connector.header
    if retry is None:
        retry = header.get("retry")
    if isinstance(retry, Mapping):
        retry = RetryPolicy.from_json(retry)
    if pricing is None:
        pricing = header.get("pricing")
    if isinstance(pricing, Mapping):
        pricing = pricing_from_json(pricing)
    return LifecycleExperiment(connector, retry=retry, pricing=pricing,
                               clock=clock)


def llm_dryrun(arch: str, seq_len: int, devices: int, kind: str = "train",
               hw: str = "tpu-v5e", hbm_fraction: float = 1.0):
    """Fast-tier LLM deployment scoring: the analytic roofline cost model
    over (mesh × sharding × batch × kernel × precision) — see
    :class:`repro.workloads.llm.LLMDryrunConnector`.  Returns the bare
    connector, so the spec's ``retry``/``pricing``/``virtual_clock`` blocks
    apply."""
    from ...workloads.llm import LLMDryrunConnector
    return LLMDryrunConnector(arch, seq_len=seq_len, devices=devices,
                              kind=kind, hw=hw, hbm_fraction=hbm_fraction)


def llm_walltime(arch: str, seq_len: int, devices: int = 1,
                 kind: str = "train", repeats: int = 3, smoke: bool = True):
    """Slow-tier LLM deployment microbench: a timed jitted step of the real
    model — see :class:`repro.workloads.llm.LLMWalltimeConnector`."""
    from ...workloads.llm import LLMWalltimeConnector
    return LLMWalltimeConnector(arch, seq_len=seq_len, devices=devices,
                                kind=kind, repeats=repeats, smoke=smoke)


register_experiment("quad", quad)
register_experiment("cloud-deploy", cloud_deploy)
register_experiment("cloud-sla", cloud_sla)
register_experiment("linear-shift", linear_shift)
register_experiment("trace-replay", trace_replay)
register_experiment("llm-dryrun", llm_dryrun)
register_experiment("llm-walltime", llm_walltime)
