"""Provider pricing models: per-second provisioned cost, failed trials too.

Scout and Lynceus both charge the *provisioned* cost of failed and
timed-out trials, not just successful ones — otherwise a search that
provisions expensive instances which fail to benchmark looks free.  The
lifecycle bills every provisioned second (provision start through teardown,
across all retry attempts) through one of these models.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Mapping

from ..entities import Configuration

__all__ = ["PricingModel", "FlatPricing", "DimensionPricing", "pricing_from_json"]


class PricingModel(abc.ABC):
    """Maps a configuration to a provisioned-cost rate ($/second)."""

    @abc.abstractmethod
    def rate(self, configuration: Configuration) -> float:
        """Cost per provisioned second for this configuration."""

    def cost(self, configuration: Configuration, seconds: float) -> float:
        return self.rate(configuration) * max(0.0, float(seconds))

    @abc.abstractmethod
    def to_json(self) -> dict:
        """Strict-round-trippable JSON form (``kind`` selects the class)."""


@dataclass(frozen=True)
class FlatPricing(PricingModel):
    """One rate for every configuration."""

    rate_per_s: float = 0.0

    def rate(self, configuration: Configuration) -> float:
        return self.rate_per_s

    def to_json(self) -> dict:
        return {"kind": "flat", "rate_per_s": self.rate_per_s}


@dataclass(frozen=True)
class DimensionPricing(PricingModel):
    """Rate keyed on one dimension's value (e.g. the instance type).

    ``rates`` is a tuple of ``(value, rate)`` pairs (tuple, not dict, so the
    model is hashable and its JSON form is order-stable); unknown values fall
    back to ``default``.
    """

    dimension: str = "instance"
    rates: tuple = ()
    default: float = 0.0

    def rate(self, configuration: Configuration) -> float:
        value = configuration.get(self.dimension)
        for v, r in self.rates:
            if v == value:
                return float(r)
        return self.default

    def to_json(self) -> dict:
        return {"kind": "dimension", "dimension": self.dimension,
                "rates": {str(v): r for v, r in self.rates},
                "default": self.default}


def pricing_from_json(d: Mapping[str, Any]) -> PricingModel:
    kind = d.get("kind")
    if kind == "flat":
        return FlatPricing(rate_per_s=float(d.get("rate_per_s", 0.0)))
    if kind == "dimension":
        rates = tuple(sorted((str(k), float(v))
                             for k, v in dict(d.get("rates", {})).items()))
        return DimensionPricing(dimension=str(d.get("dimension", "instance")),
                                rates=rates,
                                default=float(d.get("default", 0.0)))
    raise ValueError(f"unknown pricing kind {kind!r} (expected flat|dimension)")
