"""Phased actuation lifecycle for cloud experiments (ROADMAP: actuation layer).

The paper's experiments are cloud actuations — provision resources, run a
benchmark, parse metrics, tear down — but a bare
:class:`~repro.core.actions.Experiment` is a single opaque ``measure()``
call, so provisioning failures, retries, and provisioned-but-unmeasured cost
are invisible to the store and the optimizers.  This package splits the
lifecycle into phases and adapts it back onto the standard experiment
interface, so ``DiscoverySpace.sample`` and all four execution backends work
unchanged:

* :class:`~repro.core.connector.base.ExperimentConnector` — the four-phase
  interface: ``provision(config) -> Deployment``, ``run(deployment) -> raw``,
  ``parse(raw) -> {prop: value}``, ``teardown(deployment)``.
* :class:`~repro.core.connector.lifecycle.LifecycleExperiment` — adapts any
  connector into an :class:`Experiment`, driving the phases under a
  :class:`~repro.core.connector.retry.RetryPolicy` (per-phase attempts,
  exponential backoff with deterministic jitter on the injectable ``Clock``,
  idempotent teardown always attempted) and a
  :class:`~repro.core.connector.pricing.PricingModel` that charges
  per-second provisioned cost to every trial *including failed ones*.
* :class:`~repro.core.connector.trace.TraceConnector` — replays captured
  ``(config -> phase outcomes, metrics, durations)`` JSONL traces, including
  recorded provisioning failures and retry sequences, so CI and benches
  exercise the full actuation path with zero cloud spend and zero wall-clock
  sleeps (``FakeClock``).

Failure taxonomy (from :mod:`repro.core.actions`):
:class:`~repro.core.actions.ProvisioningError` is the *infrastructure's*
fault and retryable; :class:`~repro.core.actions.MeasurementError` is the
*configuration's* fault and terminal.  Exhausted retries surface as a
``MeasurementError`` carrying a :class:`~repro.core.actions.FailureRecord`
(phase, reason, attempts, cost) that the execution layer persists through
``StoreBackend.record_failure``.
"""

from __future__ import annotations

from .base import Deployment, ExperimentConnector
from .lifecycle import PROVISIONED_COST, LifecycleExperiment
from .pricing import DimensionPricing, FlatPricing, PricingModel, pricing_from_json
from .retry import RetryPolicy
from .trace import TraceConnector, load_trace, record_trace, write_trace

__all__ = [
    "Deployment", "ExperimentConnector", "LifecycleExperiment",
    "PROVISIONED_COST", "RetryPolicy", "PricingModel", "FlatPricing",
    "DimensionPricing", "pricing_from_json", "TraceConnector",
    "load_trace", "record_trace", "write_trace",
]
