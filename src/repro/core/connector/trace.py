"""Recorded-trace capture and replay for the actuation lifecycle.

A *trace* is a JSONL file: one header line (experiment identity, property
names, and the retry/pricing blocks it was captured under) followed by one
line per trial holding the configuration, the ordered phase attempts
(``{"phase", "ok", "s", "reason"?}``), and the parsed properties (or null
for a failed trial).  :func:`record_trace` captures one from any existing
experiment — phase-accurate when the experiment is a
:class:`~repro.core.connector.lifecycle.LifecycleExperiment`, synthesized
(free provision, timed run) for monolithic ones.  :class:`TraceConnector`
replays it: every recorded phase outcome, provisioning failure, retry
sequence, and duration is re-enacted by sleeping on the *injected* clock, so
a ``FakeClock`` replay advances virtual time (making billed costs
byte-identical to the recording) while performing zero real sleeps and zero
cloud spend.
"""

from __future__ import annotations

import json
import os
from typing import Any, List, Mapping, Optional, Sequence, Tuple, Union

from ..actions import Experiment, MeasurementError, ProvisioningError
from ..clock import SYSTEM_CLOCK, Clock
from ..entities import Configuration, canonical_json
from .base import Deployment, ExperimentConnector

__all__ = ["TraceConnector", "record_trace", "write_trace", "load_trace",
           "TRACE_FORMAT"]

TRACE_FORMAT = "actuation-v1"


# ---------------------------------------------------------------------------
# Trace I/O
# ---------------------------------------------------------------------------


def write_trace(path: str, header: Mapping[str, Any],
                trials: Sequence[Mapping[str, Any]]) -> None:
    """Write a trace file atomically (tmp + rename)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        f.write(canonical_json(dict(header)) + "\n")
        for t in trials:
            f.write(canonical_json(dict(t)) + "\n")
    os.replace(tmp, path)


def load_trace(path: str) -> Tuple[dict, List[dict]]:
    """Load a trace file: ``(header, trials)``."""
    with open(path, "r", encoding="utf-8") as f:
        lines = [ln for ln in (ln.strip() for ln in f) if ln]
    if not lines:
        raise ValueError(f"empty trace file {path!r}")
    header = json.loads(lines[0])
    if header.get("trace") != TRACE_FORMAT:
        raise ValueError(
            f"{path!r} is not an actuation trace "
            f"(trace={header.get('trace')!r}, expected {TRACE_FORMAT!r})")
    return header, [json.loads(ln) for ln in lines[1:]]


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


class TraceConnector(ExperimentConnector):
    """Replay a captured trace as a live connector.

    Identity (name/version/params) comes from the trace header, so a replay
    reconciles with the original experiment's stored provenance — the same
    surface, measured from a recording instead of the cloud.

    Each trial's recorded attempt sequence is consumed event-by-event:
    ``provision`` calls consume recorded provision outcomes (raising
    :class:`ProvisioningError` for recorded infrastructure failures after
    sleeping the recorded duration on the injected clock), ``run``/``parse``/
    ``teardown`` likewise.  After a trial completes (teardown) its cursor
    resets, so re-measuring a digest replays identically.  If a replay
    policy allows more provision attempts than were recorded for a failing
    trial, the last recorded failure repeats (zero extra virtual time) so
    the trial still converges to the recorded outcome.
    """

    def __init__(self, trace: Union[str, Tuple[Mapping[str, Any], Sequence[Mapping[str, Any]]]],
                 clock: Clock = SYSTEM_CLOCK):
        if isinstance(trace, (str, os.PathLike)):
            header, trials = load_trace(os.fspath(trace))
        else:
            header, trials = trace
        self._header = dict(header)
        self.name = str(self._header.get("name", "trace-replay"))
        self.version = str(self._header.get("version", "1"))
        self._params = dict(self._header.get("params", {}))
        self._props = tuple(self._header.get("properties", ()))
        self.clock = clock
        self._trials = {}
        for t in trials:
            digest = t.get("digest") or Configuration.make(t["config"]).digest
            self._trials[digest] = dict(t)
        self._cursor = {d: 0 for d in self._trials}

    @property
    def header(self) -> dict:
        return dict(self._header)

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return self._params

    @property
    def observed_properties(self) -> Sequence[str]:
        return self._props

    def __len__(self) -> int:
        return len(self._trials)

    # -- event cursor --------------------------------------------------------

    def _trial(self, digest: str) -> dict:
        try:
            return self._trials[digest]
        except KeyError:
            raise MeasurementError(
                f"configuration {digest} is not in the recorded trace "
                f"({len(self._trials)} trials)") from None

    def _next(self, digest: str, phase: str) -> Optional[dict]:
        """Consume the next recorded event if it matches ``phase``.

        Returns None when the recording has no (more) events for this phase —
        the caller decides whether that is benign (optional teardown event)
        or should repeat the last recorded outcome (exhausted provisions).
        """
        attempts = self._trial(digest).get("attempts", [])
        i = self._cursor.get(digest, 0)
        if i < len(attempts) and attempts[i].get("phase") == phase:
            self._cursor[digest] = i + 1
            return attempts[i]
        return None

    # -- phases ---------------------------------------------------------------

    def provision(self, configuration: Configuration) -> Deployment:
        digest = configuration.digest
        trial = self._trial(digest)
        ev = self._next(digest, "provision")
        if ev is None:
            # recording exhausted: repeat the last provision outcome
            evs = [a for a in trial.get("attempts", []) if a.get("phase") == "provision"]
            if not evs:
                raise MeasurementError(
                    f"trace trial {digest} has no recorded provision events")
            last = evs[-1]
            if last.get("ok"):
                return Deployment(ident=f"trace-{digest[:12]}",
                                  configuration=configuration,
                                  created_at=self.clock.time(), handle=digest)
            raise ProvisioningError(str(last.get("reason", "recorded provisioning failure")))
        self.clock.sleep(float(ev.get("s", 0.0)))
        if not ev.get("ok"):
            raise ProvisioningError(str(ev.get("reason", "recorded provisioning failure")))
        return Deployment(ident=f"trace-{digest[:12]}", configuration=configuration,
                          created_at=self.clock.time(), handle=digest)

    def run(self, deployment: Deployment) -> Any:
        digest = deployment.handle
        trial = self._trial(digest)
        ev = self._next(digest, "run")
        if ev is not None:
            self.clock.sleep(float(ev.get("s", 0.0)))
            if not ev.get("ok"):
                if ev.get("retryable"):
                    raise ProvisioningError(str(ev.get("reason", "recorded run flake")))
                raise MeasurementError(str(ev.get("reason", "recorded run failure")))
        return digest

    def parse(self, raw: Any) -> Mapping[str, float]:
        digest = raw
        trial = self._trial(digest)
        ev = self._next(digest, "parse")
        if ev is not None:
            self.clock.sleep(float(ev.get("s", 0.0)))
            if not ev.get("ok"):
                raise MeasurementError(str(ev.get("reason", "recorded parse failure")))
        props = trial.get("properties")
        if props is None:
            raise MeasurementError(f"trace trial {digest} recorded no properties")
        return {str(k): float(v) for k, v in props.items()}

    def teardown(self, deployment: Deployment) -> None:
        digest = deployment.handle
        ev = self._next(digest, "teardown")
        if ev is not None:
            self.clock.sleep(float(ev.get("s", 0.0)))
        # full replay done: reset so a re-measure replays identically
        self._cursor[digest] = 0


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


class _RecordingConnector(ExperimentConnector):
    """Delegate to a real connector, logging every phase call into a sink."""

    def __init__(self, inner: ExperimentConnector, clock: Clock, sink: list):
        self.inner = inner
        self.clock = clock
        self.sink = sink
        self.name = inner.name
        self.version = inner.version

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return self.inner.parameterization

    @property
    def observed_properties(self) -> Sequence[str]:
        return self.inner.observed_properties

    def _call(self, phase: str, fn, *args):
        t0 = self.clock.time()
        try:
            out = fn(*args)
        except (ProvisioningError, MeasurementError) as err:
            ev = {"phase": phase, "ok": False, "s": self.clock.time() - t0,
                  "reason": str(err)}
            if phase == "run" and isinstance(err, ProvisioningError):
                ev["retryable"] = True
            self.sink.append(ev)
            raise
        self.sink.append({"phase": phase, "ok": True, "s": self.clock.time() - t0})
        return out

    def provision(self, configuration: Configuration) -> Deployment:
        return self._call("provision", self.inner.provision, configuration)

    def run(self, deployment: Deployment) -> Any:
        return self._call("run", self.inner.run, deployment)

    def parse(self, raw: Any) -> Mapping[str, float]:
        return self._call("parse", self.inner.parse, raw)

    def teardown(self, deployment: Deployment) -> None:
        return self._call("teardown", self.inner.teardown, deployment)


def record_trace(experiment: Experiment,
                 configurations: Sequence[Configuration],
                 path: Optional[str] = None,
                 clock: Clock = SYSTEM_CLOCK) -> Tuple[dict, List[dict]]:
    """Capture a trace by actually measuring ``configurations``.

    Lifecycle experiments are instrumented per-phase (true durations, true
    retry sequences); monolithic experiments get a synthesized lifecycle
    (free provision, the whole ``measure()`` as the run phase).  Failed
    trials (``MeasurementError``) are recorded with their phase outcomes and
    null properties; crashes propagate.
    """
    from .lifecycle import LifecycleExperiment  # local import: cycle

    header = {"trace": TRACE_FORMAT, "name": experiment.name,
              "version": experiment.version}
    trials: List[dict] = []

    if isinstance(experiment, LifecycleExperiment):
        header["params"] = json.loads(canonical_json(dict(experiment.connector.parameterization)))
        header["properties"] = list(experiment.connector.observed_properties)
        header["retry"] = experiment.retry.to_json()
        if experiment.pricing is not None:
            header["pricing"] = experiment.pricing.to_json()
        events: list = []
        probe = LifecycleExperiment(
            _RecordingConnector(experiment.connector, clock, events),
            retry=experiment.retry, pricing=experiment.pricing, clock=clock)
        for c in configurations:
            del events[:]
            try:
                props = dict(probe.measure(c))
                props.pop("provisioned_cost", None)  # re-billed at replay
            except MeasurementError:
                props = None
            trials.append({"config": c.as_dict(), "digest": c.digest,
                           "attempts": list(events), "properties": props})
    else:
        header["params"] = json.loads(canonical_json(dict(experiment.parameterization)))
        header["properties"] = list(experiment.observed_properties)
        for c in configurations:
            t0 = clock.time()
            try:
                props = {k: float(v) for k, v in experiment.measure(c).items()}
                attempts = [{"phase": "provision", "ok": True, "s": 0.0},
                            {"phase": "run", "ok": True, "s": clock.time() - t0},
                            {"phase": "parse", "ok": True, "s": 0.0},
                            {"phase": "teardown", "ok": True, "s": 0.0}]
            except MeasurementError as err:
                props = None
                rec = getattr(err, "failure", None)
                phase = rec.phase if rec is not None else "run"
                attempts = [{"phase": "provision", "ok": True, "s": 0.0}] \
                    if phase != "provision" else []
                attempts.append({"phase": phase, "ok": False,
                                 "s": clock.time() - t0, "reason": str(err)})
            trials.append({"config": c.as_dict(), "digest": c.digest,
                           "attempts": attempts, "properties": props})

    if path is not None:
        write_trace(path, header, trials)
    return header, trials
