"""The four-phase actuation interface and its deployment handle."""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

from ..entities import Configuration

__all__ = ["Deployment", "ExperimentConnector"]


@dataclass
class Deployment:
    """A handle on provisioned infrastructure for one trial.

    ``handle`` is whatever the connector needs to run against / tear down
    (a compiled executable, a Terraform state path, an instance id);
    ``meta`` carries free-form annotations.  ``torn_down`` makes teardown
    idempotent at the lifecycle level: a second teardown of the same handle
    is a no-op, so retry paths and zombie cleanups can always call it.
    """

    ident: str
    configuration: Configuration
    created_at: float = 0.0
    handle: Any = None
    meta: dict = field(default_factory=dict)
    torn_down: bool = False


class ExperimentConnector(abc.ABC):
    """A phased cloud actuation: provision -> run -> parse -> teardown.

    Identity mirrors :class:`~repro.core.actions.Experiment`:
    ``(name, version, parameterization)`` — the adapting
    :class:`~repro.core.connector.lifecycle.LifecycleExperiment` exposes it
    unchanged, so stored provenance for a connector-backed experiment is
    byte-identical to its monolithic predecessor's.

    Phase contract:

    * ``provision`` raises :class:`~repro.core.actions.ProvisioningError`
      for infrastructure faults (retryable) and
      :class:`~repro.core.actions.MeasurementError` when the configuration
      itself cannot be deployed (terminal).
    * ``run`` returns an opaque raw result; infrastructure flakes mid-run may
      raise ``ProvisioningError`` (retried on the same deployment up to the
      policy's ``run_attempts``).
    * ``parse`` maps the raw result to ``{property: float}``; the default
      passes a mapping through.
    * ``teardown`` must be idempotent; the lifecycle always attempts it,
      on success, failure, and crash paths alike.
    """

    name: str = "connector"
    version: str = "1"

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {}

    @property
    @abc.abstractmethod
    def observed_properties(self) -> Sequence[str]:
        """Names of the properties ``parse`` produces."""

    @abc.abstractmethod
    def provision(self, configuration: Configuration) -> Deployment:
        """Stand up infrastructure for one trial."""

    @abc.abstractmethod
    def run(self, deployment: Deployment) -> Any:
        """Execute the benchmark; returns a raw result for ``parse``."""

    def parse(self, raw: Any) -> Mapping[str, float]:
        """Extract property values from a raw result."""
        return dict(raw)

    def teardown(self, deployment: Deployment) -> None:
        """Release the deployment's resources (idempotent; default free)."""
