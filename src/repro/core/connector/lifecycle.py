"""Adapter: any `ExperimentConnector` as a standard `Experiment`.

This is the seam that keeps the rest of the system unchanged: the Discovery
Space, the claims machinery, and all four execution backends see an ordinary
``measure()`` call, while underneath the lifecycle drives provision / run /
parse / teardown with retries, billing, and structured failure provenance.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

from ..actions import (Experiment, FailureRecord, MeasurementError,
                       ProvisioningError)
from ..clock import SYSTEM_CLOCK, Clock
from ..entities import Configuration
from .base import Deployment, ExperimentConnector
from .pricing import PricingModel
from .retry import RetryPolicy

__all__ = ["LifecycleExperiment", "PROVISIONED_COST"]

#: Property name under which the billed provisioned cost of a *successful*
#: trial is stored (failed trials carry their cost on the failure row).
PROVISIONED_COST = "provisioned_cost"


class LifecycleExperiment(Experiment):
    """Drive an :class:`ExperimentConnector` through the actuation lifecycle.

    Identity (name / version / parameterization) delegates to the connector,
    so converting a monolithic experiment into a connector behind this
    adapter leaves stored provenance — and therefore draw-for-draw optimizer
    trajectories — untouched.  A :class:`PricingModel`, when present, *does*
    join the parameterization (it changes the observed surface by adding the
    ``provisioned_cost`` property); the :class:`RetryPolicy` does not (it
    changes robustness, not the measured values).

    Failure semantics: ``ProvisioningError`` from ``provision`` is retried
    per the policy (fresh infrastructure each try, backoff on the injected
    clock); once exhausted, the trial fails as a ``MeasurementError``
    carrying a :class:`FailureRecord` with ``phase="provision"``, the attempt
    count, and every billed second — failed trials are not free.  ``run`` /
    ``parse`` failures tear down first, then fail with their own phase
    provenance.  Teardown is always attempted, once, even on crash paths.
    """

    def __init__(self, connector: ExperimentConnector,
                 retry: Optional[RetryPolicy] = None,
                 pricing: Optional[PricingModel] = None,
                 clock: Clock = SYSTEM_CLOCK):
        self.connector = connector
        self.retry = retry or RetryPolicy()
        self.pricing = pricing
        self.clock = clock

    # -- identity delegates to the connector --------------------------------

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.connector.name

    @property
    def version(self) -> str:  # type: ignore[override]
        return self.connector.version

    @property
    def parameterization(self) -> Mapping[str, Any]:
        params = dict(self.connector.parameterization)
        if self.pricing is not None:
            params["pricing"] = self.pricing.to_json()
        return params

    @property
    def observed_properties(self) -> Sequence[str]:
        props = tuple(self.connector.observed_properties)
        if self.pricing is not None and PROVISIONED_COST not in props:
            props = props + (PROVISIONED_COST,)
        return props

    # -- the lifecycle -------------------------------------------------------

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        clock = self.clock
        digest = configuration.digest
        charged = 0.0

        def bill(t0: float) -> None:
            nonlocal charged
            if self.pricing is not None:
                charged += self.pricing.cost(configuration, clock.time() - t0)

        # -- provision: infrastructure faults retry on fresh resources ------
        deployment: Optional[Deployment] = None
        tries = 0
        while deployment is None:
            tries += 1
            t0 = clock.time()
            try:
                deployment = self.connector.provision(configuration)
                bill(t0)  # the successful attempt's window is provisioned time
            except ProvisioningError as err:
                bill(t0)  # partially provisioned time is still billed
                if tries >= self.retry.provision_attempts:
                    raise MeasurementError(
                        f"provisioning failed after {tries} attempts: {err}",
                        failure=FailureRecord("provision", str(err), tries, charged),
                    ) from err
                clock.sleep(self.retry.delay(tries, digest))
            except MeasurementError as err:
                bill(t0)  # the configuration itself is non-deployable: terminal
                raise MeasurementError(
                    str(err),
                    failure=err.failure
                    or FailureRecord("provision", str(err), tries, charged),
                ) from err

        # -- run / parse: teardown always attempted, window fully billed ----
        t0 = clock.time()
        phase = "run"
        try:
            raw = self._run(deployment, digest)
            phase = "parse"
            props = dict(self.connector.parse(raw))
        except ProvisioningError as err:
            self._teardown(deployment)
            bill(t0)
            raise MeasurementError(
                f"{phase} failed after {self.retry.run_attempts} attempts: {err}",
                failure=FailureRecord(phase, str(err), self.retry.run_attempts, charged),
            ) from err
        except MeasurementError as err:
            self._teardown(deployment)
            bill(t0)
            rec = err.failure or FailureRecord(phase, str(err), 1, 0.0)
            raise MeasurementError(
                str(err),
                failure=FailureRecord(rec.phase, rec.reason, rec.attempts, charged),
            ) from err
        except BaseException:
            self._teardown(deployment)  # crashes still release infrastructure
            raise
        self._teardown(deployment)
        bill(t0)

        out = {k: float(v) for k, v in props.items()}
        if self.pricing is not None:
            out[PROVISIONED_COST] = charged
        return out

    def _run(self, deployment: Deployment, digest: str) -> Any:
        """Run phase; infrastructure flakes retry on the same deployment."""
        tries = 0
        while True:
            tries += 1
            try:
                return self.connector.run(deployment)
            except ProvisioningError:
                if tries >= self.retry.run_attempts:
                    raise
                self.clock.sleep(self.retry.delay(tries, digest + ":run"))

    def _teardown(self, deployment: Deployment) -> None:
        """Idempotent teardown: attempted exactly once per deployment, and
        teardown's own failures never mask the trial's outcome."""
        if deployment.torn_down:
            return
        deployment.torn_down = True
        try:
            self.connector.teardown(deployment)
        except Exception:
            pass
