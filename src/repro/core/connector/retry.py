"""Per-phase retry policy with deterministic backoff jitter."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

from ..entities import content_hash

__all__ = ["RetryPolicy"]


@dataclass(frozen=True)
class RetryPolicy:
    """How the lifecycle retries infrastructure faults.

    Only :class:`~repro.core.actions.ProvisioningError` is retried —
    ``provision`` up to ``provision_attempts`` total tries (each on fresh
    infrastructure), ``run`` up to ``run_attempts`` on the same deployment.
    Backoff is exponential (``backoff_s * backoff_factor**(attempt-1)``,
    capped at ``max_backoff_s``) and slept on the *injected* clock, so a
    ``FakeClock`` replay performs zero real sleeps.

    Jitter is deterministic: keyed on the content hash of
    ``(key, attempt)`` rather than a live RNG, so a recorded retry sequence
    replays with identical delays — and identical charged costs — every time.
    """

    provision_attempts: int = 3
    run_attempts: int = 1
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 60.0
    jitter: float = 0.1

    def __post_init__(self):
        if self.provision_attempts < 1 or self.run_attempts < 1:
            raise ValueError("retry policy needs at least one attempt per phase")
        if self.backoff_s < 0 or self.backoff_factor < 1 or not (0 <= self.jitter <= 1):
            raise ValueError(
                f"bad retry policy: backoff_s={self.backoff_s}, "
                f"backoff_factor={self.backoff_factor}, jitter={self.jitter}")

    def delay(self, attempt: int, key: str = "") -> float:
        """Backoff before attempt ``attempt + 1`` (deterministic in ``key``)."""
        base = min(self.max_backoff_s,
                   self.backoff_s * self.backoff_factor ** max(0, attempt - 1))
        if not self.jitter or not base:
            return base
        h = int(content_hash([key, attempt])[:8], 16) / 0xFFFFFFFF
        return base * (1.0 + self.jitter * (2.0 * h - 1.0))

    def to_json(self) -> dict:
        return {"provision_attempts": self.provision_attempts,
                "run_attempts": self.run_attempts,
                "backoff_s": self.backoff_s,
                "backoff_factor": self.backoff_factor,
                "max_backoff_s": self.max_backoff_s,
                "jitter": self.jitter}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "RetryPolicy":
        return RetryPolicy(
            provision_attempts=int(d.get("provision_attempts", 3)),
            run_attempts=int(d.get("run_attempts", 1)),
            backoff_s=float(d.get("backoff_s", 1.0)),
            backoff_factor=float(d.get("backoff_factor", 2.0)),
            max_backoff_s=float(d.get("max_backoff_s", 60.0)),
            jitter=float(d.get("jitter", 0.1)))
