"""Action space ``A`` — the methodology of a study (paper §III-B1).

Each element of A is an :class:`Experiment` that can be applied to a
configuration to obtain measured property values.  The Action space defines
the measurable properties of interest *and their provenance*: every value in
the store records which experiment produced it.

Surrogate predictors (paper §IV-4) are experiments too: adding one to an
action space produces a *new* Discovery Space (``A*_pred``), keeping
predicted values distinguishable from measured ones by provenance.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence

from .entities import Configuration, content_hash

__all__ = ["Experiment", "FunctionExperiment", "SurrogateExperiment", "ActionSpace",
           "MeasurementError", "ProvisioningError", "FailureRecord"]


class Experiment(abc.ABC):
    """A measurement that maps a configuration to property values.

    Identity is ``(name, version, parameterization)`` — hermetic and hashable
    so stored provenance is meaningful across processes and machines.
    """

    name: str = "experiment"
    version: str = "1"

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {}

    @property
    def identifier(self) -> str:
        h = content_hash({"p": dict(self.parameterization)})[:8]
        return f"{self.name}-v{self.version}-{h}"

    @property
    def predicted(self) -> bool:
        """True when this experiment is a surrogate model, not a measurement."""
        return False

    @property
    def deferred(self) -> bool:
        """True when sample() must NOT auto-run this experiment (§IV-4)."""
        return False

    @property
    @abc.abstractmethod
    def observed_properties(self) -> Sequence[str]:
        """Names of the properties this experiment measures."""

    @abc.abstractmethod
    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        """Run the experiment; returns ``{property: value}``.

        May raise :class:`MeasurementError` for non-deployable configurations;
        the Discovery Space records these as failed samples (the paper's
        "non-deployable points", excluded from CDFs).
        """


class MeasurementError(RuntimeError):
    """A configuration could not be deployed / measured.

    This is *the configuration's* fault (a non-deployable point, paper
    §III-C): retrying the same configuration would fail again, so the
    Discovery Space records a failed sample and moves on.  The optional
    ``failure`` attribute carries structured provenance (a
    :class:`FailureRecord`) from the actuation lifecycle; the execution
    layer persists it through ``StoreBackend.record_failure``.
    """

    def __init__(self, message: str = "", failure: "Optional[FailureRecord]" = None):
        super().__init__(message)
        self.failure = failure


class ProvisioningError(RuntimeError):
    """Infrastructure failed to provision / respond — NOT the configuration's
    fault.  Retryable: the actuation lifecycle's :class:`RetryPolicy` backs
    off and tries again; only after exhausting its attempts does the trial
    become a failed sample (wrapped as :class:`MeasurementError` with
    ``phase="provision"`` provenance)."""


@dataclass(frozen=True)
class FailureRecord:
    """Structured provenance for one failed trial.

    ``phase`` names the lifecycle phase that gave up (``provision`` / ``run``
    / ``parse`` / ``measure`` for monolithic experiments), ``reason`` is the
    human-readable cause, ``attempts`` counts tries of the failing phase, and
    ``cost`` is the provisioned-but-unmeasured spend charged to the trial
    (the Scout/Lynceus accounting: failed trials are not free).
    """

    phase: str
    reason: str
    attempts: int = 1
    cost: float = 0.0

    def to_json(self) -> dict:
        return {"phase": self.phase, "reason": self.reason,
                "attempts": self.attempts, "cost": self.cost}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "FailureRecord":
        return FailureRecord(phase=str(d["phase"]), reason=str(d["reason"]),
                             attempts=int(d.get("attempts", 1)),
                             cost=float(d.get("cost", 0.0)))


class DeferredExperiment(Experiment):
    """A real experiment kept in an action space as apply-on-demand.

    Used by ``A*_pred`` (paper §IV-4): the surrogate predictor provides cheap
    values, while "the action space of A* can still be applied to points to
    get the real values".  A deferred experiment keeps the *identity* of the
    wrapped experiment — stored values reconcile normally — but
    ``DiscoverySpace.sample`` will not execute it automatically; call
    :meth:`measure` explicitly (or sample through the original space) to get
    real values.
    """

    def __init__(self, wrapped: Experiment):
        self.wrapped = wrapped

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.wrapped.name

    @property
    def version(self) -> str:  # type: ignore[override]
        return self.wrapped.version

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return self.wrapped.parameterization

    @property
    def identifier(self) -> str:
        return self.wrapped.identifier

    @property
    def predicted(self) -> bool:
        return self.wrapped.predicted

    @property
    def deferred(self) -> bool:
        return True

    @property
    def observed_properties(self) -> Sequence[str]:
        return self.wrapped.observed_properties

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        return self.wrapped.measure(configuration)


@dataclass
class FunctionExperiment(Experiment):
    """Wrap a plain callable as an experiment (tests, synthetic workloads)."""

    fn: Callable[[Configuration], Mapping[str, float]]
    properties: tuple = ()
    name: str = "fn"
    version: str = "1"
    params: dict = field(default_factory=dict)

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return self.params

    @property
    def observed_properties(self) -> Sequence[str]:
        return self.properties

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        out = self.fn(configuration)
        missing = set(self.properties) - set(out)
        if missing:
            raise MeasurementError(f"experiment {self.name} missing properties {missing}")
        try:
            return {k: float(v) for k, v in out.items() if k in self.properties}
        except (TypeError, ValueError) as err:
            # A non-float-coercible value is a bad *measurement*, not a crash
            # of the worker: surface it as a failed trial so the search keeps
            # going instead of killing the backend.
            raise MeasurementError(
                f"experiment {self.name} returned a non-numeric property value "
                f"for configuration {configuration.digest}: {err}") from err


@dataclass
class SurrogateExperiment(Experiment):
    """A predictor experiment installed by knowledge transfer (paper §IV-4).

    ``model`` maps a *source-space property value* to a predicted target value
    (the linear surrogate fitted by RSSC), and ``source`` supplies the source
    value for a configuration (typically a lookup into the source Discovery
    Space through the configuration mapping).
    """

    source: Callable[[Configuration], float]
    model: Callable[[float], float]
    property_name: str = "metric"
    name: str = "surrogate"
    version: str = "1"
    params: dict = field(default_factory=dict)

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return self.params

    @property
    def predicted(self) -> bool:
        return True

    @property
    def observed_properties(self) -> Sequence[str]:
        return (self.property_name,)

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        return {self.property_name: float(self.model(self.source(configuration)))}


@dataclass(frozen=True)
class ActionSpace:
    """The methodology: an ordered set of experiments."""

    experiments: tuple

    def __post_init__(self):
        # property -> experiment resolution happens on every measurement and
        # every optimizer tell; build the map once (first experiment claiming
        # a property wins, matching the original scan order).  The instance
        # is frozen, so the cache is installed via object.__setattr__; it is
        # not a dataclass field, so eq/hash/repr are unchanged and
        # `extended()` (which builds a new instance) rebuilds it naturally.
        by_prop = {}
        for e in self.experiments:
            for p in e.observed_properties:
                by_prop.setdefault(p, e)
        object.__setattr__(self, "_experiment_by_property", by_prop)

    @staticmethod
    def make(exps: Sequence[Experiment]) -> "ActionSpace":
        return ActionSpace(experiments=tuple(exps))

    @property
    def observed_properties(self) -> tuple:
        out = []
        for e in self.experiments:
            for p in e.observed_properties:
                if p not in out:
                    out.append(p)
        return tuple(out)

    @property
    def identifiers(self) -> tuple:
        return tuple(e.identifier for e in self.experiments)

    @property
    def digest(self) -> str:
        return content_hash(list(self.identifiers))

    def extended(self, *exps: Experiment) -> "ActionSpace":
        """A new action space with extra experiments (e.g. a surrogate)."""
        return ActionSpace(experiments=self.experiments + tuple(exps))

    def experiment_for(self, prop: str) -> Experiment:
        try:
            return self._experiment_by_property[prop]
        except KeyError:
            raise KeyError(prop) from None
