"""The common context: a shared, schema'd sample store (paper §III-C3).

One SQLite database holds *all* sample information for *all* Discovery
Spaces, in one generic schema that mirrors the mathematical structure of a
Discovery Space:

* ``configurations`` — elements of Ω, keyed by content hash (identity is the
  configuration's value assignment, NOT which study created it — this is what
  lets two studies reconcile to the same row, Fig. 4).
* ``property_values`` — measured/predicted values with experiment provenance.
* ``spaces`` — registered Discovery Space definitions.
* ``operations`` — named operations (optimizer runs etc.) on a space.
* ``records`` — the time-resolved sampling record: one row per sample event
  per space, with a per-operation sequence number, an action tag
  (``measured`` / ``reused`` / ``predicted`` / ``failed``) and a timestamp.

WAL mode makes the store safe for concurrent access by multiple processes —
the "distributed shared sample store" of paper §III-D (the paper used a SQL
database; so do we).

Concurrent writers
------------------

The store is written to from worker threads (``DiscoverySpace.sample_batch``)
and from independent worker processes sharing one database file.  Two
invariants make that safe:

* every statement runs — and its result rows are fully fetched — while
  holding the connection (a per-thread connection for file-backed stores, a
  single lock-guarded connection for ``:memory:``), so cursors never escape
  to racing threads;
* per-operation sequence numbers are allocated *inside* the insert statement
  (``INSERT ... SELECT COALESCE(MAX(seq),-1)+1``), which executes atomically
  under SQLite's single-writer lock: concurrent appenders get gapless,
  non-duplicated ``seq`` values with no read-modify-write window.
"""

from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
import uuid
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Iterable, Mapping, Optional, Sequence

from .entities import Configuration, PropertyValue, canonical_json

__all__ = ["SampleStore", "RecordEntry"]

_SCHEMA = """
CREATE TABLE IF NOT EXISTS configurations (
    digest     TEXT PRIMARY KEY,
    config     TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS property_values (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    config_digest TEXT NOT NULL,
    property      TEXT NOT NULL,
    value         REAL NOT NULL,
    experiment_id TEXT NOT NULL,
    predicted     INTEGER NOT NULL DEFAULT 0,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS pv_config ON property_values(config_digest, experiment_id);
CREATE TABLE IF NOT EXISTS spaces (
    space_id   TEXT PRIMARY KEY,
    space_json TEXT NOT NULL,
    actions    TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS operations (
    operation_id TEXT PRIMARY KEY,
    space_id     TEXT NOT NULL,
    kind         TEXT NOT NULL,
    meta         TEXT NOT NULL DEFAULT '{}',
    created_at   REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS records (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    space_id      TEXT NOT NULL,
    operation_id  TEXT NOT NULL,
    seq           INTEGER NOT NULL,
    config_digest TEXT NOT NULL,
    action        TEXT NOT NULL,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS rec_space ON records(space_id, operation_id, seq);
CREATE TABLE IF NOT EXISTS value_claims (
    config_digest TEXT NOT NULL,
    experiment_id TEXT NOT NULL,
    owner         TEXT NOT NULL,
    created_at    REAL NOT NULL,
    PRIMARY KEY (config_digest, experiment_id)
);
CREATE INDEX IF NOT EXISTS rec_digest ON records(space_id, config_digest);
CREATE TABLE IF NOT EXISTS work_items (
    item_id       TEXT PRIMARY KEY,
    space_id      TEXT NOT NULL,
    config_digest TEXT NOT NULL,
    status        TEXT NOT NULL DEFAULT 'queued',
    owner         TEXT,
    action        TEXT,
    error         TEXT,
    created_at    REAL NOT NULL,
    claimed_at    REAL,
    finished_at   REAL
);
CREATE INDEX IF NOT EXISTS wi_queue ON work_items(space_id, status, created_at);
"""

# Allocates the next per-operation sequence number and inserts the record in
# ONE statement: atomic under SQLite's writer lock, so concurrent appenders
# (threads or processes) can never observe the same MAX(seq).
_APPEND_SQL = (
    "INSERT INTO records(space_id, operation_id, seq, config_digest, action, created_at)"
    " SELECT ?, ?, COALESCE(MAX(seq), -1) + 1, ?, ?, ?"
    " FROM records WHERE space_id=? AND operation_id=?"
)


@dataclass(frozen=True)
class RecordEntry:
    """One entry of a space's time-resolved sampling record."""

    space_id: str
    operation_id: str
    seq: int
    config_digest: str
    action: str
    created_at: float


class SampleStore:
    """SQLite-backed common context.  Thread-safe; multi-process safe (WAL)."""

    def __init__(self, path: str = ":memory:"):
        self.path = path
        self._local = threading.local()
        self._memory_conn: Optional[sqlite3.Connection] = None
        self._memory_lock = threading.Lock()
        if path != ":memory:":
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
        with self._conn() as conn:
            conn.executescript(_SCHEMA)

    # -- connection management ------------------------------------------------

    @contextmanager
    def _conn(self):
        """Yield a connection that is exclusively ours for the duration.

        ``:memory:`` stores share one connection across threads, serialized
        by a lock; file-backed stores get one connection per thread (SQLite
        WAL serializes writers itself).  All statement execution AND row
        fetching must happen inside this context.
        """
        if self.path == ":memory:":
            with self._memory_lock:
                if self._memory_conn is None:
                    self._memory_conn = sqlite3.connect(
                        ":memory:", check_same_thread=False, isolation_level=None
                    )
                yield self._memory_conn
            return
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=60.0, isolation_level=None)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.execute("PRAGMA busy_timeout=60000")
            self._local.conn = conn
        yield conn

    def _write(self, sql: str, params: Sequence = ()) -> int:
        """Execute a write statement; returns the last inserted rowid."""
        with self._conn() as conn:
            return conn.execute(sql, params).lastrowid

    def _rows(self, sql: str, params: Sequence = ()) -> list:
        """Execute a query and fetch all rows while holding the connection."""
        with self._conn() as conn:
            return conn.execute(sql, params).fetchall()

    @contextmanager
    def transaction(self):
        """Group writes into one SQLite transaction (``BEGIN IMMEDIATE``).

        Used by the batch write paths so N inserts hit the WAL once; the
        IMMEDIATE lock also gives multi-statement atomicity to concurrent
        writer processes.
        """
        with self._conn() as conn:
            conn.execute("BEGIN IMMEDIATE")
            try:
                yield conn
            except BaseException:
                conn.execute("ROLLBACK")
                raise
            conn.execute("COMMIT")

    # -- spaces & operations ----------------------------------------------------

    def register_space(self, space_id: str, space_json: Mapping, action_ids: Sequence[str]) -> None:
        self._write(
            "INSERT OR IGNORE INTO spaces(space_id, space_json, actions, created_at)"
            " VALUES (?,?,?,?)",
            (space_id, canonical_json(space_json), canonical_json(list(action_ids)), time.time()),
        )

    def register_operation(self, operation_id: str, space_id: str, kind: str,
                           meta: Optional[Mapping] = None) -> None:
        self._write(
            "INSERT OR IGNORE INTO operations(operation_id, space_id, kind, meta, created_at)"
            " VALUES (?,?,?,?,?)",
            (operation_id, space_id, kind, canonical_json(meta or {}), time.time()),
        )

    def operations_for(self, space_id: str) -> list:
        rows = self._rows(
            "SELECT operation_id, kind, meta, created_at FROM operations"
            " WHERE space_id=? ORDER BY created_at",
            (space_id,),
        )
        return [
            {"operation_id": r[0], "kind": r[1], "meta": json.loads(r[2]), "created_at": r[3]}
            for r in rows
        ]

    # -- configurations -----------------------------------------------------------

    def put_configuration(self, config: Configuration) -> str:
        digest = config.digest
        self._write(
            "INSERT OR IGNORE INTO configurations(digest, config, created_at) VALUES (?,?,?)",
            (digest, canonical_json(config.values), time.time()),
        )
        return digest

    def get_configuration(self, digest: str) -> Optional[Configuration]:
        rows = self._rows("SELECT config FROM configurations WHERE digest=?", (digest,))
        if not rows:
            return None
        pairs = json.loads(rows[0][0])
        return Configuration(values=tuple((k, _thaw(v)) for k, v in pairs))

    # -- property values (measurement results) --------------------------------------

    def put_values(self, config_digest: str, values: Iterable[PropertyValue]) -> None:
        """Insert one experiment's values in a single transaction, so a
        concurrent reader can never observe a half-written measurement."""
        rows = [
            (config_digest, v.name, float(v.value), v.experiment_id,
             1 if v.predicted else 0, v.timestamp)
            for v in values
        ]
        if not rows:
            return
        with self.transaction() as conn:
            conn.executemany(
                "INSERT INTO property_values"
                " (config_digest, property, value, experiment_id, predicted, created_at)"
                " VALUES (?,?,?,?,?,?)",
                rows,
            )

    def get_values(self, config_digest: str,
                   experiment_ids: Optional[Sequence[str]] = None) -> list:
        sql = ("SELECT property, value, experiment_id, predicted, created_at"
               " FROM property_values WHERE config_digest=?")
        params: list = [config_digest]
        if experiment_ids is not None:
            marks = ",".join("?" * len(experiment_ids))
            sql += f" AND experiment_id IN ({marks})"
            params.extend(experiment_ids)
        sql += " ORDER BY id"
        return [
            PropertyValue(name=r[0], value=r[1], experiment_id=r[2],
                          predicted=bool(r[3]), timestamp=r[4])
            for r in self._rows(sql, params)
        ]

    def has_values(self, config_digest: str, experiment_id: str) -> bool:
        rows = self._rows(
            "SELECT 1 FROM property_values WHERE config_digest=? AND experiment_id=? LIMIT 1",
            (config_digest, experiment_id),
        )
        return bool(rows)

    # -- measurement claims (measure-once across concurrent investigators) -----

    def claim_experiment(self, config_digest: str, experiment_id: str,
                         owner: str = "") -> bool:
        """Atomically claim the right to measure (configuration, experiment).

        Concurrent investigators sharing one store race through
        ``has_values -> measure``; without arbitration both deploy the same
        experiment (paying twice).  ``INSERT OR IGNORE`` on the primary key
        decides a single winner: True means *we* measure, False means someone
        else is (or already did) — wait via :meth:`wait_for_values`.

        Claims persist after a successful measurement (the values themselves
        make re-claiming moot) and are :meth:`release_claim`-ed on failure so
        waiters can take over instead of stalling.
        """
        with self._conn() as conn:
            cur = conn.execute(
                "INSERT OR IGNORE INTO value_claims"
                "(config_digest, experiment_id, owner, created_at) VALUES (?,?,?,?)",
                (config_digest, experiment_id, owner, time.time()),
            )
            return cur.rowcount == 1

    def release_claim(self, config_digest: str, experiment_id: str) -> None:
        self._write(
            "DELETE FROM value_claims WHERE config_digest=? AND experiment_id=?",
            (config_digest, experiment_id),
        )

    def steal_claim(self, config_digest: str, experiment_id: str,
                    owner: str, older_than_s: float) -> bool:
        """Atomically take over a claim whose owner is presumed dead.

        Succeeds only if the claim row is older than ``older_than_s`` — a
        single UPDATE under the writer lock, so of N waiters racing to steal
        the same stale claim exactly one wins (the winner refreshes
        ``created_at``, which falsifies the WHERE clause for the rest).
        """
        with self._conn() as conn:
            cur = conn.execute(
                "UPDATE value_claims SET owner=?, created_at=?"
                " WHERE config_digest=? AND experiment_id=? AND created_at < ?",
                (owner, time.time(), config_digest, experiment_id,
                 time.time() - older_than_s),
            )
            return cur.rowcount == 1

    def claim_exists(self, config_digest: str, experiment_id: str) -> bool:
        rows = self._rows(
            "SELECT 1 FROM value_claims WHERE config_digest=? AND experiment_id=? LIMIT 1",
            (config_digest, experiment_id),
        )
        return bool(rows)

    def sweep_stale_claims(self, older_than_s: float) -> int:
        """Reap claims older than ``older_than_s`` (presumed-crashed owners).

        Complements :meth:`steal_claim`, which only fires once a waiter has
        burned its full timeout on that specific cell: the periodic sweep
        clears *all* stale claims up front, so waiters that arrive later race
        a fresh :meth:`claim_experiment` instead of a dead owner's row.
        Deleting the claim of a *successful* measurement is harmless — the
        landed values short-circuit re-claiming.  Returns the reap count.
        """
        with self._conn() as conn:
            cur = conn.execute(
                "DELETE FROM value_claims WHERE created_at < ?",
                (time.time() - older_than_s,),
            )
            return cur.rowcount

    def release_claims_owned_by(self, owner: str) -> int:
        """Release every claim held by ``owner`` (exact match or
        ``owner:<thread>`` children) — the cleanup path when an investigator
        observes one of its worker processes die mid-measurement.  Returns
        the number of claims released."""
        with self._conn() as conn:
            cur = conn.execute(
                "DELETE FROM value_claims WHERE owner = ? OR owner LIKE ?",
                (owner, owner + ":%"),
            )
            return cur.rowcount

    def wait_for_values(self, config_digest: str, experiment_id: str,
                        timeout_s: float = 60.0) -> bool:
        """Wait for another investigator's in-flight measurement to land.

        Returns True when values appeared (reuse them), False when the claim
        vanished without values (the owner failed — take over) or the timeout
        expired (the owner is presumed dead — take over).
        """
        deadline = time.monotonic() + timeout_s
        poll = 0.005
        while time.monotonic() < deadline:
            if self.has_values(config_digest, experiment_id):
                return True
            if not self.claim_exists(config_digest, experiment_id):
                return False
            time.sleep(poll)
            poll = min(poll * 2, 0.1)
        return False

    # -- the work-item queue (store-rendezvous execution, paper §III-D) ---------

    def enqueue_work(self, space_id: str, config_digest: str) -> str:
        """Queue one (space, configuration) measurement for remote workers.

        The shared store is the *only* coordination point (§III-D): any
        worker process on any host holding this database file (or a network
        mount of it) can claim the item, run the experiments, and land values
        through the normal measurement-claim arbitration.  Returns the item
        id used to poll for completion.
        """
        item_id = uuid.uuid4().hex
        self._write(
            "INSERT INTO work_items(item_id, space_id, config_digest, status, created_at)"
            " VALUES (?,?,?,'queued',?)",
            (item_id, space_id, config_digest, time.time()),
        )
        return item_id

    def claim_work(self, owner: str, space_id: Optional[str] = None) -> Optional[dict]:
        """Atomically pop the oldest queued work item (None when idle).

        Claiming is an ``UPDATE ... WHERE status='queued'`` on a specific
        item id: under SQLite's single-writer lock exactly one of N racing
        workers flips the row to ``running``; the losers retry on the next
        oldest item.
        """
        for _ in range(16):
            rows = self._rows(
                "SELECT item_id, space_id, config_digest FROM work_items"
                " WHERE status='queued'" +
                (" AND space_id=?" if space_id is not None else "") +
                " ORDER BY created_at, item_id LIMIT 1",
                (space_id,) if space_id is not None else (),
            )
            if not rows:
                return None
            item_id = rows[0][0]
            with self._conn() as conn:
                cur = conn.execute(
                    "UPDATE work_items SET status='running', owner=?, claimed_at=?"
                    " WHERE item_id=? AND status='queued'",
                    (owner, time.time(), item_id),
                )
                if cur.rowcount == 1:
                    return {"item_id": item_id, "space_id": rows[0][1],
                            "config_digest": rows[0][2]}
        return None

    def finish_work(self, item_id: str, action: str,
                    error: Optional[str] = None,
                    owner: Optional[str] = None) -> bool:
        """Land a claimed work item's outcome for the enqueuer to collect.

        Guarded: only a ``running`` item is finished, and when ``owner`` is
        given it must still hold the claim — a stale worker whose item was
        re-queued (and possibly re-claimed by the surviving fleet) cannot
        overwrite the re-execution's outcome.  Returns False for such stale
        finishes (the caller should simply move on).
        """
        sql = ("UPDATE work_items SET status='done', action=?, error=?,"
               " finished_at=? WHERE item_id=? AND status='running'")
        params: list = [action, error, time.time(), item_id]
        if owner is not None:
            sql += " AND owner=?"
            params.append(owner)
        with self._conn() as conn:
            return conn.execute(sql, params).rowcount == 1

    def fetch_work_results(self, item_ids: Sequence[str]) -> dict:
        """``{item_id: (action, error)}`` for the finished subset of ids.

        Chunked so huge in-flight batches stay under SQLite's
        bound-parameter limit (999 on older builds).
        """
        out: dict = {}
        item_ids = list(item_ids)
        for i in range(0, len(item_ids), 500):
            chunk = item_ids[i:i + 500]
            marks = ",".join("?" * len(chunk))
            rows = self._rows(
                f"SELECT item_id, action, error FROM work_items"
                f" WHERE status='done' AND item_id IN ({marks})",
                chunk,
            )
            out.update({r[0]: (r[1], r[2]) for r in rows})
        return out

    def requeue_stale_work(self, older_than_s: float) -> int:
        """Re-queue running items whose worker went silent (crash tolerance):
        an item claimed more than ``older_than_s`` ago without a result goes
        back to ``queued`` for the surviving fleet.  Returns the count."""
        with self._conn() as conn:
            cur = conn.execute(
                "UPDATE work_items SET status='queued', owner=NULL, claimed_at=NULL"
                " WHERE status='running' AND claimed_at < ?",
                (time.time() - older_than_s,),
            )
            return cur.rowcount

    def pending_work(self, space_id: Optional[str] = None) -> int:
        sql = "SELECT COUNT(*) FROM work_items WHERE status IN ('queued','running')"
        params: tuple = ()
        if space_id is not None:
            sql += " AND space_id=?"
            params = (space_id,)
        return int(self._rows(sql, params)[0][0])

    # -- the time-resolved sampling record --------------------------------------------

    def next_seq(self, space_id: str, operation_id: str) -> int:
        """The sequence number the next append would get.  Informational only:
        appenders must NOT pre-compute this — :meth:`append_record` allocates
        atomically inside its insert."""
        rows = self._rows(
            "SELECT COALESCE(MAX(seq), -1) + 1 FROM records WHERE space_id=? AND operation_id=?",
            (space_id, operation_id),
        )
        return int(rows[0][0])

    def append_record(self, space_id: str, operation_id: str, config_digest: str,
                      action: str) -> RecordEntry:
        """Append one sampling event, allocating its per-operation ``seq``
        atomically (safe under concurrent threads and processes)."""
        now = time.time()
        rowid = self._write(
            _APPEND_SQL,
            (space_id, operation_id, config_digest, action, now,
             space_id, operation_id),
        )
        rows = self._rows("SELECT seq FROM records WHERE id=?", (rowid,))
        return RecordEntry(space_id, operation_id, int(rows[0][0]), config_digest, action, now)

    def append_records(self, space_id: str, operation_id: str,
                       events: Sequence[Sequence[str]]) -> list:
        """Append ``[(config_digest, action), ...]`` in order, as one
        transaction.  Returns the created :class:`RecordEntry` list.

        This is the deterministic-ordering write path of
        ``DiscoverySpace.sample_batch``: results gathered from a worker pool
        are recorded in submission order regardless of completion order.
        """
        if not events:
            return []
        now = time.time()
        first_rowid = None
        with self.transaction() as conn:
            for digest, action in events:
                cur = conn.execute(
                    _APPEND_SQL,
                    (space_id, operation_id, digest, action, now,
                     space_id, operation_id),
                )
                if first_rowid is None:
                    first_rowid = cur.lastrowid
            rows = conn.execute(
                "SELECT seq FROM records WHERE id>=? AND space_id=? AND operation_id=?"
                " ORDER BY id",
                (first_rowid, space_id, operation_id),
            ).fetchall()
        return [
            RecordEntry(space_id, operation_id, int(r[0]), digest, action, now)
            for r, (digest, action) in zip(rows, events)
        ]

    def records_for(self, space_id: str, operation_id: Optional[str] = None) -> list:
        sql = ("SELECT space_id, operation_id, seq, config_digest, action, created_at"
               " FROM records WHERE space_id=?")
        params: list = [space_id]
        if operation_id is not None:
            sql += " AND operation_id=?"
            params.append(operation_id)
        sql += " ORDER BY id"
        return [RecordEntry(*r) for r in self._rows(sql, params)]

    def has_record(self, space_id: str, config_digest: str,
                   include_failed: bool = False) -> bool:
        """Point query: is this configuration in the space's sampling record?
        Indexed (``rec_digest``), so membership checks don't rebuild the full
        sampled-digest set the way :meth:`sampled_digests` does."""
        sql = "SELECT 1 FROM records WHERE space_id=? AND config_digest=?"
        if not include_failed:
            sql += " AND action != 'failed'"
        return bool(self._rows(sql + " LIMIT 1", (space_id, config_digest)))

    def sampled_digests(self, space_id: str, include_failed: bool = False) -> list:
        """Distinct configuration digests in this space's sampling record,
        ordered by first appearance (deterministic across serial/parallel
        runs that recorded the same event sequence)."""
        sql = ("SELECT config_digest FROM records WHERE space_id=?"
               "{} GROUP BY config_digest ORDER BY MIN(id)")
        sql = sql.format("" if include_failed else " AND action != 'failed'")
        return [r[0] for r in self._rows(sql, (space_id,))]

    # -- statistics --------------------------------------------------------------------

    def count_measured(self, space_id: Optional[str] = None) -> int:
        if space_id is None:
            rows = self._rows("SELECT COUNT(*) FROM records WHERE action='measured'")
        else:
            rows = self._rows(
                "SELECT COUNT(*) FROM records WHERE action='measured' AND space_id=?",
                (space_id,),
            )
        return int(rows[0][0])

    def close(self) -> None:
        if self.path == ":memory:":
            with self._memory_lock:
                if self._memory_conn is not None:
                    self._memory_conn.close()
                    self._memory_conn = None
        else:
            conn = getattr(self._local, "conn", None)
            if conn is not None:
                conn.close()
                self._local.conn = None


def _thaw(v: Any) -> Any:
    if isinstance(v, list):
        return tuple(_thaw(x) for x in v)
    return v
