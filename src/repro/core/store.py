"""The common context: a shared, schema'd sample store (paper §III-C3).

One SQLite database holds *all* sample information for *all* Discovery
Spaces, in one generic schema that mirrors the mathematical structure of a
Discovery Space:

* ``configurations`` — elements of Ω, keyed by content hash (identity is the
  configuration's value assignment, NOT which study created it — this is what
  lets two studies reconcile to the same row, Fig. 4).
* ``property_values`` — measured/predicted values with experiment provenance.
* ``spaces`` — registered Discovery Space definitions.
* ``operations`` — named operations (optimizer runs etc.) on a space.
* ``records`` — the time-resolved sampling record: one row per sample event
  per space, with a per-operation sequence number, an action tag
  (``measured`` / ``reused`` / ``predicted`` / ``failed``) and a timestamp.

WAL mode makes the store safe for concurrent access by multiple processes —
the "distributed shared sample store" of paper §III-D (the paper used a SQL
database; so do we).
"""

from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
from dataclasses import dataclass
from typing import Any, Iterable, Mapping, Optional, Sequence

from .entities import Configuration, PropertyValue, canonical_json

__all__ = ["SampleStore", "RecordEntry"]

_SCHEMA = """
CREATE TABLE IF NOT EXISTS configurations (
    digest     TEXT PRIMARY KEY,
    config     TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS property_values (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    config_digest TEXT NOT NULL,
    property      TEXT NOT NULL,
    value         REAL NOT NULL,
    experiment_id TEXT NOT NULL,
    predicted     INTEGER NOT NULL DEFAULT 0,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS pv_config ON property_values(config_digest, experiment_id);
CREATE TABLE IF NOT EXISTS spaces (
    space_id   TEXT PRIMARY KEY,
    space_json TEXT NOT NULL,
    actions    TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS operations (
    operation_id TEXT PRIMARY KEY,
    space_id     TEXT NOT NULL,
    kind         TEXT NOT NULL,
    meta         TEXT NOT NULL DEFAULT '{}',
    created_at   REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS records (
    id            INTEGER PRIMARY KEY AUTOINCREMENT,
    space_id      TEXT NOT NULL,
    operation_id  TEXT NOT NULL,
    seq           INTEGER NOT NULL,
    config_digest TEXT NOT NULL,
    action        TEXT NOT NULL,
    created_at    REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS rec_space ON records(space_id, operation_id, seq);
"""


@dataclass(frozen=True)
class RecordEntry:
    """One entry of a space's time-resolved sampling record."""

    space_id: str
    operation_id: str
    seq: int
    config_digest: str
    action: str
    created_at: float


class SampleStore:
    """SQLite-backed common context.  Thread-safe; multi-process safe (WAL)."""

    def __init__(self, path: str = ":memory:"):
        self.path = path
        self._local = threading.local()
        self._memory_conn: Optional[sqlite3.Connection] = None
        if path != ":memory:":
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
        conn = self._connect()
        with conn:
            conn.executescript(_SCHEMA)

    # -- connection management ------------------------------------------------

    def _connect(self) -> sqlite3.Connection:
        if self.path == ":memory:":
            # a single shared connection (threads serialize on a lock)
            if self._memory_conn is None:
                self._memory_conn = sqlite3.connect(
                    ":memory:", check_same_thread=False, isolation_level=None
                )
                self._memory_lock = threading.Lock()
            return self._memory_conn
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=60.0, isolation_level=None)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            self._local.conn = conn
        return conn

    def _execute(self, sql: str, params: Sequence = ()) -> sqlite3.Cursor:
        conn = self._connect()
        if self.path == ":memory:":
            with self._memory_lock:
                return conn.execute(sql, params)
        return conn.execute(sql, params)

    # -- spaces & operations ----------------------------------------------------

    def register_space(self, space_id: str, space_json: Mapping, action_ids: Sequence[str]) -> None:
        self._execute(
            "INSERT OR IGNORE INTO spaces(space_id, space_json, actions, created_at)"
            " VALUES (?,?,?,?)",
            (space_id, canonical_json(space_json), canonical_json(list(action_ids)), time.time()),
        )

    def register_operation(self, operation_id: str, space_id: str, kind: str,
                           meta: Optional[Mapping] = None) -> None:
        self._execute(
            "INSERT OR IGNORE INTO operations(operation_id, space_id, kind, meta, created_at)"
            " VALUES (?,?,?,?,?)",
            (operation_id, space_id, kind, canonical_json(meta or {}), time.time()),
        )

    def operations_for(self, space_id: str) -> list:
        cur = self._execute(
            "SELECT operation_id, kind, meta, created_at FROM operations"
            " WHERE space_id=? ORDER BY created_at",
            (space_id,),
        )
        return [
            {"operation_id": r[0], "kind": r[1], "meta": json.loads(r[2]), "created_at": r[3]}
            for r in cur.fetchall()
        ]

    # -- configurations -----------------------------------------------------------

    def put_configuration(self, config: Configuration) -> str:
        digest = config.digest
        self._execute(
            "INSERT OR IGNORE INTO configurations(digest, config, created_at) VALUES (?,?,?)",
            (digest, canonical_json(config.values), time.time()),
        )
        return digest

    def get_configuration(self, digest: str) -> Optional[Configuration]:
        cur = self._execute("SELECT config FROM configurations WHERE digest=?", (digest,))
        row = cur.fetchone()
        if row is None:
            return None
        pairs = json.loads(row[0])
        return Configuration(values=tuple((k, _thaw(v)) for k, v in pairs))

    # -- property values (measurement results) --------------------------------------

    def put_values(self, config_digest: str, values: Iterable[PropertyValue]) -> None:
        for v in values:
            self._execute(
                "INSERT INTO property_values"
                " (config_digest, property, value, experiment_id, predicted, created_at)"
                " VALUES (?,?,?,?,?,?)",
                (config_digest, v.name, float(v.value), v.experiment_id,
                 1 if v.predicted else 0, v.timestamp),
            )

    def get_values(self, config_digest: str,
                   experiment_ids: Optional[Sequence[str]] = None) -> list:
        sql = ("SELECT property, value, experiment_id, predicted, created_at"
               " FROM property_values WHERE config_digest=?")
        params: list = [config_digest]
        if experiment_ids is not None:
            marks = ",".join("?" * len(experiment_ids))
            sql += f" AND experiment_id IN ({marks})"
            params.extend(experiment_ids)
        sql += " ORDER BY id"
        cur = self._execute(sql, params)
        return [
            PropertyValue(name=r[0], value=r[1], experiment_id=r[2],
                          predicted=bool(r[3]), timestamp=r[4])
            for r in cur.fetchall()
        ]

    def has_values(self, config_digest: str, experiment_id: str) -> bool:
        cur = self._execute(
            "SELECT 1 FROM property_values WHERE config_digest=? AND experiment_id=? LIMIT 1",
            (config_digest, experiment_id),
        )
        return cur.fetchone() is not None

    # -- the time-resolved sampling record --------------------------------------------

    def next_seq(self, space_id: str, operation_id: str) -> int:
        cur = self._execute(
            "SELECT COALESCE(MAX(seq), -1) + 1 FROM records WHERE space_id=? AND operation_id=?",
            (space_id, operation_id),
        )
        return int(cur.fetchone()[0])

    def append_record(self, space_id: str, operation_id: str, config_digest: str,
                      action: str) -> RecordEntry:
        seq = self.next_seq(space_id, operation_id)
        now = time.time()
        self._execute(
            "INSERT INTO records(space_id, operation_id, seq, config_digest, action, created_at)"
            " VALUES (?,?,?,?,?,?)",
            (space_id, operation_id, seq, config_digest, action, now),
        )
        return RecordEntry(space_id, operation_id, seq, config_digest, action, now)

    def records_for(self, space_id: str, operation_id: Optional[str] = None) -> list:
        sql = ("SELECT space_id, operation_id, seq, config_digest, action, created_at"
               " FROM records WHERE space_id=?")
        params: list = [space_id]
        if operation_id is not None:
            sql += " AND operation_id=?"
            params.append(operation_id)
        sql += " ORDER BY id"
        cur = self._execute(sql, params)
        return [RecordEntry(*r) for r in cur.fetchall()]

    def sampled_digests(self, space_id: str, include_failed: bool = False) -> list:
        """Distinct configuration digests in this space's sampling record."""
        sql = "SELECT DISTINCT config_digest FROM records WHERE space_id=?"
        if not include_failed:
            sql += " AND action != 'failed'"
        cur = self._execute(sql, (space_id,))
        return [r[0] for r in cur.fetchall()]

    # -- statistics --------------------------------------------------------------------

    def count_measured(self, space_id: Optional[str] = None) -> int:
        if space_id is None:
            cur = self._execute("SELECT COUNT(*) FROM records WHERE action='measured'")
        else:
            cur = self._execute(
                "SELECT COUNT(*) FROM records WHERE action='measured' AND space_id=?",
                (space_id,),
            )
        return int(cur.fetchone()[0])

    def close(self) -> None:
        if self.path == ":memory:":
            if self._memory_conn is not None:
                self._memory_conn.close()
                self._memory_conn = None
        else:
            conn = getattr(self._local, "conn", None)
            if conn is not None:
                conn.close()
                self._local.conn = None


def _thaw(v: Any) -> Any:
    if isinstance(v, list):
        return tuple(_thaw(x) for x in v)
    return v
