"""Transfer criteria and surrogate models for RSSC (paper §IV-3, §IV-4).

The criteria: a linear regression between the source and target values of the
representative sub-space must have correlation ``r > 0.7`` and slope p-value
``< 0.01`` (null: slope == 0).  When met, the fitted line becomes the
surrogate model installed in the target's action space.

Also implements the paper's prediction-quality metrics (§V-B2): best%, top5%,
and rank resolution.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np
from scipy import stats

__all__ = ["TransferCriteria", "TransferAssessment", "LinearSurrogate",
           "assess_transfer", "prediction_quality", "PredictionQuality"]


@dataclass(frozen=True)
class TransferCriteria:
    min_r: float = 0.7
    max_p: float = 0.01


@dataclass
class LinearSurrogate:
    slope: float
    intercept: float

    def __call__(self, source_value: float) -> float:
        return self.slope * float(source_value) + self.intercept

    def batch(self, source_values: np.ndarray) -> np.ndarray:
        return self.slope * np.asarray(source_values, dtype=float) + self.intercept


@dataclass
class TransferAssessment:
    r: float
    p_value: float
    transferable: bool
    surrogate: Optional[LinearSurrogate]
    n_points: int

    def summary(self) -> dict:
        return {
            "r": round(self.r, 4),
            "p_value": float(f"{self.p_value:.3g}"),
            "transfer": self.transferable,
            "n_points": self.n_points,
        }


def assess_transfer(source_values: Sequence[float], target_values: Sequence[float],
                    criteria: TransferCriteria = TransferCriteria()) -> TransferAssessment:
    """Apply the paper's go/no-go transfer criteria to paired representative
    sub-space measurements."""
    x = np.asarray(source_values, dtype=float)
    y = np.asarray(target_values, dtype=float)
    if len(x) != len(y) or len(x) < 3:
        return TransferAssessment(0.0, 1.0, False, None, len(x))
    if np.allclose(x, x[0]) or np.allclose(y, y[0]):
        return TransferAssessment(0.0, 1.0, False, None, len(x))
    fit = stats.linregress(x, y)
    # |r| — the paper transfers on strong linear relationships; a strong
    # negative correlation is equally informative for ranking, and the slope
    # sign is carried by the surrogate.
    transferable = abs(fit.rvalue) > criteria.min_r and fit.pvalue < criteria.max_p
    surrogate = LinearSurrogate(float(fit.slope), float(fit.intercept)) if transferable else None
    return TransferAssessment(
        r=float(fit.rvalue), p_value=float(fit.pvalue),
        transferable=bool(transferable), surrogate=surrogate, n_points=len(x),
    )


# ---------------------------------------------------------------------------
# Prediction-quality metrics (paper §V-B2)
# ---------------------------------------------------------------------------


@dataclass
class PredictionQuality:
    best_pct: float        # performance percentile of predicted-best config
    top5_pct: float        # fraction of actual top-5 in predicted top-5
    rank_resolution: float # avg |error| expressed in rank distance
    savings_pct: float     # time saved vs brute force = 1 - measured/total

    def summary(self) -> dict:
        return {
            "best%": round(100 * self.best_pct, 1),
            "top5%": round(100 * self.top5_pct, 1),
            "rank_resolution": round(self.rank_resolution, 1),
            "%savings": round(100 * self.savings_pct, 1),
        }


def prediction_quality(predicted: np.ndarray, actual: np.ndarray,
                       n_measured: int, mode: str = "min") -> PredictionQuality:
    """Score a surrogate's predictions against exhaustive ground truth.

    * best%  — CDF percentile (w.r.t. actual values) of the configuration the
      predictor ranks best.  100% == the predictor's top pick is the true best.
    * top5%  — overlap of predicted and actual top-5 sets.
    * rank resolution — X such that the mean absolute prediction error equals
      the mean actual-value gap between configurations X ranks apart.
    * savings — 1 - n_measured / n_total (the brute-force baseline measures
      everything).
    """
    predicted = np.asarray(predicted, dtype=float)
    actual = np.asarray(actual, dtype=float)
    n = len(actual)
    sign = 1.0 if mode == "min" else -1.0
    pa, aa = sign * predicted, sign * actual

    # best%: percentile of predicted-best in the actual CDF (higher = better)
    i_pred_best = int(np.argmin(pa))
    best_pct = float((aa > aa[i_pred_best]).sum() / max(n - 1, 1))

    # top5 overlap
    k = min(5, n)
    top_pred = set(np.argsort(pa)[:k].tolist())
    top_true = set(np.argsort(aa)[:k].tolist())
    top5_pct = len(top_pred & top_true) / k

    # rank resolution: mean |err| / mean adjacent-rank gap
    err = np.abs(predicted - actual).mean()
    sorted_actual = np.sort(actual)
    gaps = np.diff(sorted_actual)
    mean_gap = gaps.mean() if len(gaps) else 0.0
    rank_res = float(err / mean_gap) if mean_gap > 0 else float(n)
    rank_res = min(rank_res, float(n))

    savings = 1.0 - n_measured / max(n, 1)
    return PredictionQuality(best_pct=best_pct, top5_pct=top5_pct,
                             rank_resolution=max(rank_res, 1.0), savings_pct=savings)
