"""Training stack: optimizer, schedules, train step."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from .train_step import make_train_step, train_state_specs

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at",
           "make_train_step", "train_state_specs"]
