"""Sharded training step: loss → grads (with microbatch accumulation) →
AdamW update, built for pjit with explicit in/out shardings.

Microbatch gradient accumulation runs as ``lax.scan`` over microbatches —
with batch sharded over DP axes, XLA schedules each microbatch's gradient
reduce-scatter to overlap the next microbatch's compute (the standard
latency-hiding structure; enabled further by the scheduler flags set in
``launch/train.py``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (DeploymentConfig, batch_specs, param_specs)
from ..models.config import ModelConfig
from ..models.model import LMModel
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "train_state_specs", "init_train_state"]


def train_state_specs(model: LMModel, deployment: DeploymentConfig) -> dict:
    pspecs = param_specs(model.logical_specs(), deployment)
    return {"params": pspecs,
            "m": pspecs,
            "v": pspecs,
            "step": P()}


def init_train_state(model: LMModel, key) -> dict:
    params = model.init(key)
    opt = adamw_init(params)
    return {"params": params, "m": opt["m"], "v": opt["v"], "step": opt["step"]}


def make_train_step(model: LMModel, deployment: DeploymentConfig, mesh: Mesh,
                    opt_cfg: Optional[AdamWConfig] = None, jit: bool = True):
    """Returns (train_step, state_specs, batch_spec_tree).

    ``train_step(state, batch) -> (state, metrics)``; batch is the GLOBAL
    batch {tokens/embeds, labels}, sharded per ``batch_specs``.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    n_micro = deployment.microbatches
    state_specs = train_state_specs(model, deployment)
    bspecs = batch_specs(model.cfg, deployment, kind="train")

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    cdt = deployment.model_options().policy.compute_dtype

    def _maybe_cast(params):
        if not deployment.cast_params_once:
            return params
        # one fp32->bf16 stream per STEP; microbatches then read bf16
        # weights (the in-layer .astype becomes a no-op)
        return jax.tree.map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 and p.ndim > 1
            else p, params)

    def grads_of(params, batch):
        params = _maybe_cast(params)
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # microbatch accumulation: split the per-device batch rows
        def split(x):
            b = x.shape[0]
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc_loss, acc_grads = acc
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads)), metrics

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grad_sum), metrics = jax.lax.scan(body, zero, micro)
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda x: x[-1], metrics), \
            jax.tree.map(lambda g: g * inv, grad_sum)

    def train_step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        params, opt, opt_metrics = adamw_update(
            grads, {"m": state["m"], "v": state["v"], "step": state["step"]},
            state["params"], opt_cfg)
        new_state = {"params": params, "m": opt["m"], "v": opt["v"],
                     "step": opt["step"]}
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    if not jit:
        return train_step, state_specs, bspecs

    metric_specs = {k: P() for k in
                    ("loss", "ce", "aux", "grad_norm", "lr")}
    step_jit = jax.jit(
        train_step,
        in_shardings=(jax.tree.map(lambda p: NamedSharding(mesh, p), state_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
                      jax.tree.map(lambda p: NamedSharding(mesh, p), bspecs,
                                   is_leaf=lambda x: isinstance(x, P))),
        out_shardings=(jax.tree.map(lambda p: NamedSharding(mesh, p), state_specs,
                                    is_leaf=lambda x: isinstance(x, P)),
                       jax.tree.map(lambda p: NamedSharding(mesh, p), metric_specs,
                                    is_leaf=lambda x: isinstance(x, P))),
        donate_argnums=(0,),
    )
    return step_jit, state_specs, bspecs
