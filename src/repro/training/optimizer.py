"""AdamW with warmup+cosine schedule, pure JAX (no optax dependency).

Optimizer moments are plain pytrees mirroring the parameters, so they
inherit the parameters' sharding (ZeRO: FSDP-sharded params => FSDP-sharded
moments, no extra code).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + 0.5 * (1 - cfg.min_lr_ratio) * cfg.lr * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state: dict, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                     jnp.square(g.astype(v.dtype)), opt_state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
