"""Deterministic, restartable, sharded synthetic-token data pipeline.

Design requirements it satisfies (matching what a production loader needs):

* **Deterministic & seekable** — batch ``i`` is a pure function of
  ``(seed, i)``; restoring a checkpoint at step N resumes the exact stream
  by setting the cursor (no stateful iterators to persist).
* **Sharded** — each host materializes only its slice of the global batch
  (``host_slice``); under pjit the global batch is assembled from per-host
  shards via ``jax.make_array_from_process_local_data`` on multi-host, or
  device_put with the batch sharding on single-host.
* **Prefetch** — a background thread keeps ``prefetch`` batches ready.
* **Straggler mitigation** — ``skip_to`` lets the fault-tolerance layer skip
  a slow/poisoned shard window deterministically (all hosts agree on the
  skip by construction because the stream is stateless).

The synthetic distribution is a Zipf-like unigram mix with a Markov overlay
— enough structure that a ~100M model's loss visibly decreases within a few
hundred steps (used by ``examples/train_lm.py``).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "TokenPipeline"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.7
    prefetch: int = 2


class TokenPipeline:
    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        self.cfg = cfg
        if cfg.global_batch % host_count:
            raise ValueError("global batch must divide host count")
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self._cursor = 0
        self._queue: "queue.Queue" = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # fixed unigram distribution + permutation for the Markov overlay
        base = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._unigram = ranks ** (-cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        self._perm = base.permutation(cfg.vocab_size)

    # -- deterministic batch construction -------------------------------------

    def batch_at(self, index: int) -> dict:
        """The global batch at cursor ``index`` (host's slice only)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, index, self.host_index]))
        B, S = self.local_batch, cfg.seq_len
        draws = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._unigram)
        # Markov overlay: with probability markov_strength, token t+1 is a
        # fixed function (permutation) of the REALIZED token t — a proper
        # chain, so next-token prediction has learnable structure.
        follow = rng.uniform(size=(B, S)) < cfg.markov_strength
        seq = np.empty_like(draws)
        seq[:, 0] = draws[:, 0]
        for t in range(1, S + 1):
            seq[:, t] = np.where(follow[:, t - 1],
                                 self._perm[seq[:, t - 1]], draws[:, t])
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    # -- iteration with prefetch ------------------------------------------------

    def start(self, cursor: int = 0) -> None:
        self.stop()
        self._cursor = cursor
        self._stop.clear()

        def worker():
            i = cursor
            while not self._stop.is_set():
                batch = self.batch_at(i)
                while not self._stop.is_set():
                    try:
                        self._queue.put((i, batch), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                i += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
            while not self._queue.empty():
                self._queue.get_nowait()

    def __next__(self) -> Tuple[int, dict]:
        if self._thread is None:
            batch = self.batch_at(self._cursor)
            idx = self._cursor
            self._cursor += 1
            return idx, batch
        return self._queue.get()

    def __iter__(self) -> Iterator:
        return self

    def skip_to(self, cursor: int) -> None:
        """Straggler/poison mitigation: jump the stream (deterministic on all
        hosts)."""
        self.start(cursor)
