"""Data pipeline: deterministic synthetic token streams, sharded + prefetched."""

from .pipeline import DataConfig, TokenPipeline

__all__ = ["DataConfig", "TokenPipeline"]
