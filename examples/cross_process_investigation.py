"""Cross-process cooperative investigation over one shared store file.

The ROADMAP item "cross-process campaigns" wired end to end: two optimizer
members run in SEPARATE PROCESSES, each with its own operation, rng, and
stopping behaviour, coordinating through nothing but the shared SQLite
store (paper §III-D).  Before every ask each member folds the other
process's new sampling events into its history —
``SearchAdapter.sync_foreign``, the same incremental watermark-paged read
(``SampleStore.records_since``) the in-process ``Campaign`` uses — so both
models train on the union of the fleet's measurements while the store's
claim arbitration keeps every configuration measured at most once
fleet-wide.

Each member also reports its observed **sync latency**: for every foreign
record it folds, the time from the record's commit (its store timestamp) to
the moment the fold made it model-visible.  That is the staleness bound a
cross-process fleet trains under — with two local processes over one WAL
database it is dominated by the ask/evaluate cadence, not the store.

    PYTHONPATH=src python examples/cross_process_investigation.py [--quick]
"""

import argparse
import json
import multiprocessing as mp
import os
import tempfile
import time

import numpy as np

from repro.core import (ActionSpace, Dimension, DiscoverySpace,
                        ProbabilitySpace, SampleStore)
from repro.core.api.workloads import cloud_deploy
from repro.core.optimizers import OPTIMIZER_REGISTRY
from repro.core.optimizers.base import FOREIGN_ACTION, SearchAdapter, as_scored

METRIC = "cost_per_1k"


def build_space() -> ProbabilitySpace:
    return ProbabilitySpace.make([
        Dimension.categorical("instance", ["m5.large", "m5.xlarge",
                                           "c5.xlarge", "c5.2xlarge"]),
        Dimension.discrete("workers", [1, 2, 4, 8]),
        Dimension.discrete("batch_size", [8, 16, 32, 64]),
        Dimension.discrete("prefetch", [1, 2, 4]),
    ])


def member_process(store_path: str, label: str, optimizer: str, seed: int,
                   trials: int, out_path: str, pace_s: float) -> None:
    """One fleet member in its own process: sync foreign → ask → evaluate.

    Identical to a Campaign member's turn on the coordinator loop, except
    the 'fleet' is whatever other processes share the store file.  Sync
    latency is measured per folded record as fold-time minus the record's
    commit timestamp (same host, same wall clock)."""
    store = SampleStore(store_path)
    ds = DiscoverySpace(space=build_space(),
                        actions=ActionSpace.make([cloud_deploy()]),
                        store=store)
    adapter = SearchAdapter(ds, METRIC, "min", optimizer_name=label)
    opt = OPTIMIZER_REGISTRY[optimizer](seed=seed)
    rng = np.random.default_rng(seed)
    latencies = []
    for _ in range(trials):
        # peek the rows sync_foreign is about to fold, to timestamp them
        fresh = store.records_since(ds.space_id, adapter.record_watermark,
                                    exclude_operation=adapter.operation_id)
        folded = adapter.sync_foreign()
        now = time.time()
        if folded:
            latencies.extend(now - r.created_at for r in fresh)
        batch = as_scored(opt.ask(adapter, rng, n=1))
        if not batch:
            break
        adapter.evaluate_batch([batch[0]])
        time.sleep(pace_s)  # a real deployment takes time; let peers land
    adapter.sync_foreign()  # final fold for honest history accounting
    own = [t for t in adapter.trials if t.action != FOREIGN_ACTION]
    with open(out_path, "w") as f:
        json.dump({
            "label": label,
            "operation_id": adapter.operation_id,
            "own_trials": len(own),
            "own_measured": sum(1 for t in own if t.action == "measured"),
            "own_reused": sum(1 for t in own if t.action == "reused"),
            "foreign_trials": sum(1 for t in adapter.trials
                                  if t.action == FOREIGN_ACTION),
            "best": min((t.value for t in adapter.trials
                         if t.value is not None), default=None),
            "sync_latencies_s": latencies,
        }, f)


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="smaller budgets (CI smoke mode)")
    args = parser.parse_args(argv)
    trials = 8 if args.quick else 16
    pace_s = 0.02 if args.quick else 0.05

    members = [("tpe", "tpe", 0), ("bo-gp", "bo-gp", 1)]
    ctx = mp.get_context("spawn")  # no fork: keep worker processes hermetic
    with tempfile.TemporaryDirectory() as tmp:
        store_path = os.path.join(tmp, "store.db")
        # parent creates the store + space once; children rendezvous on it
        ds = DiscoverySpace(space=build_space(),
                            actions=ActionSpace.make([cloud_deploy()]),
                            store=SampleStore(store_path))
        t0 = time.perf_counter()
        procs, outs = [], []
        for label, optimizer, seed in members:
            out_path = os.path.join(tmp, f"{label}.json")
            outs.append(out_path)
            p = ctx.Process(target=member_process,
                            args=(store_path, label, optimizer, seed,
                                  trials, out_path, pace_s))
            p.start()
            procs.append(p)
        for p in procs:
            p.join(timeout=240)
            if p.exitcode != 0:
                raise SystemExit(f"member process failed: {p.exitcode}")
        wall = time.perf_counter() - t0
        results = [json.load(open(o)) for o in outs]

        print(f"Two-process investigation over one store file ({wall:.1f}s):")
        all_lat = []
        for r in results:
            lat = r["sync_latencies_s"]
            all_lat.extend(lat)
            lat_txt = ("no foreign records" if not lat else
                       f"sync latency median {1e3 * float(np.median(lat)):.0f}ms "
                       f"p95 {1e3 * float(np.quantile(lat, 0.95)):.0f}ms")
            print(f"  [{r['label']:5s}] op={r['operation_id'][:24]} "
                  f"own={r['own_trials']} (measured={r['own_measured']}, "
                  f"reused={r['own_reused']}) + foreign={r['foreign_trials']} "
                  f"=> best {r['best']:.3f}; {lat_txt}")

        # the cross-process sharing contract, asserted
        store = SampleStore(store_path)
        distinct = len(store.sampled_digests(ds.space_id))
        measured = store.count_measured(ds.space_id)
        for r in results:
            assert r["foreign_trials"] > 0, \
                f"{r['label']} saw no foreign history — no sharing happened"
        assert measured == distinct, "a configuration was measured twice"
        print(f"  fleet: {distinct} distinct configurations, {measured} paid "
              f"measurements (measure-once held across processes)")
        print(f"  observed store→model sync latency: median "
              f"{1e3 * float(np.median(all_lat)):.0f}ms, max "
              f"{1e3 * float(np.max(all_lat)):.0f}ms over "
              f"{len(all_lat)} folded records")


if __name__ == "__main__":
    main()
