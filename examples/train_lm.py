"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Runs the full production path on local devices: deterministic data pipeline
→ sharded train step (AdamW, grad clip, warmup+cosine) → periodic async
checkpoints → restart-safe resume.  Loss drops well below the unigram
entropy of the synthetic Markov distribution within ~200 steps.

    PYTHONPATH=src python examples/train_lm.py                   # ~100M model
    PYTHONPATH=src python examples/train_lm.py --quick           # 2-minute demo
    # kill it mid-run, re-run the same command: it resumes from the last
    # checkpoint (same final state as an uninterrupted run).
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced model, 60 steps (~2 min)")
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", "nano-100m", "--smoke", "--steps", "60",
                "--batch", "8", "--seq", "64", "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "20", "--log-every", "10"]
    else:
        argv = ["--arch", "nano-100m", "--steps", str(args.steps),
                "--batch", "2", "--seq", "128", "--lr", "1e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
                "--log-every", "10"]
    out = train_main(argv)
    print(f"[train_lm] {out}")
    if out["first_loss"] is not None and out["last_loss"] is not None \
            and out["steps_run"] >= 50:
        assert out["last_loss"] < out["first_loss"], "loss did not decrease"
        print(f"[train_lm] loss {out['first_loss']:.3f} -> "
              f"{out['last_loss']:.3f} over {out['steps_run']} steps")


if __name__ == "__main__":
    main()
