"""Two worker *processes* sharing one on-disk sample store (paper Fig. 4).

The paper's §III-D claim is that investigation can be distributed: several
optimizers/investigators run against the same Discovery Space through a
shared SQL store, reusing each other's measurements transparently.  This
demo makes that concrete — and actually concurrent:

* two OS processes open the same SQLite (WAL) store;
* each runs a batched random search over the SAME space with a different
  seed, 4 experiment-worker threads each, overlapping in time;
* measurements by one process are transparent *reuses* for the other —
  total measurement count stays == distinct configurations sampled;
* the per-operation sampling records come out gapless, and both processes
  reconcile to one consistent sample set.

    PYTHONPATH=src python examples/shared_store_workers.py
"""

import multiprocessing
import os
import tempfile
import time

import numpy as np

MEASURE_LATENCY_S = 0.005


def build_space():
    from repro.core import Dimension, ProbabilitySpace

    return ProbabilitySpace.make([
        Dimension.categorical("instance", ["m5.large", "m5.xlarge", "c5.xlarge"]),
        Dimension.discrete("workers", [1, 2, 4, 8]),
        Dimension.discrete("batch_size", [16, 32, 64]),
    ])


def build_ds(store_path):
    """Same (Ω, A) in every process => same space_id => one shared study."""
    from repro.core import ActionSpace, DiscoverySpace, FunctionExperiment, SampleStore

    def deploy_and_measure(c):
        time.sleep(MEASURE_LATENCY_S)  # pretend this deploys to a cloud
        rate = {"m5.large": 90.0, "m5.xlarge": 170.0, "c5.xlarge": 210.0}[c["instance"]]
        eff = min(1.0, 0.4 + 0.15 * np.log2(c["workers"] * c["batch_size"] / 16))
        return {"tokens_per_s": rate * c["workers"] * eff}

    exp = FunctionExperiment(fn=deploy_and_measure, properties=("tokens_per_s",),
                             name="cloud-deploy")
    return DiscoverySpace(space=build_space(), actions=ActionSpace.make([exp]),
                          store=SampleStore(store_path))


def investigate(store_path: str, seed: int, tag: str) -> None:
    """One investigator: batched ask/tell search, 4 experiment workers."""
    from repro.core.optimizers import RandomSearch, run_optimizer

    ds = build_ds(store_path)
    run = run_optimizer(RandomSearch(seed=seed), ds, "tokens_per_s", "max",
                        max_trials=24, patience=25,
                        rng=np.random.default_rng(seed),
                        batch_size=6, workers=4)
    print(f"  [{tag}] pid={os.getpid()} trials={run.num_trials} "
          f"measured={run.num_measured} reused={run.num_reused} "
          f"best={run.best.value:.1f} tokens/s")


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        store_path = os.path.join(d, "common_context.db")
        build_ds(store_path).store.close()  # create schema up front

        print("Two investigator processes, one common context:")
        ctx = multiprocessing.get_context("spawn")
        procs = [ctx.Process(target=investigate, args=(store_path, seed, tag))
                 for seed, tag in ((0, "worker-A"), (1, "worker-B"))]
        for p in procs:
            p.start()
        for p in procs:
            p.join()
        assert all(p.exitcode == 0 for p in procs)

        # Reconcile from a THIRD process's point of view (fresh handles).
        ds = build_ds(store_path)
        samples = ds.read()
        measured = ds.store.count_measured(ds.space_id)
        print(f"\nReconciled: {len(samples)} distinct configurations, "
              f"{measured} measurements total")
        print("  => every configuration was measured exactly once; overlap "
              "between the workers was reused, not re-measured")
        assert measured == len(samples) <= 36

        ops = ds.store.operations_for(ds.space_id)
        for op in ops:
            records = ds.timeseries(op["operation_id"])
            seqs = [r.seq for r in records]
            assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        print(f"  => {len(ops)} operations, all sampling records gapless")

        best = max(samples, key=lambda s: s.value("tokens_per_s"))
        print(f"  best: {dict(best.configuration.values)} "
              f"-> {best.value('tokens_per_s'):.1f} tokens/s")


if __name__ == "__main__":
    main()
