"""One investigator + two remote measurement workers, one shared store.

The paper's §III-D claim is that investigation can be distributed through a
shared SQL sample store.  This demo takes it literally with the
``QueueBackend``: the investigator process never executes an experiment —
it runs the pipelined ask/tell optimizer and submits work items as rows in
the store's ``work_items`` table, while two separate
``python -m repro.core.execution.worker`` processes (started here exactly
as you would start them on other hosts sharing the database) pull items,
run the measurement state machine, and land values through the
measurement-claim arbitration.  The store is the *only* coordination point:

* every configuration is measured exactly once, no matter which worker
  races to it;
* the investigator's sampling record comes out gapless;
* the sum of the workers' processed items equals the measurements made.

The workers run the full distributed-queue machinery: they pop queued items
*best-acquisition-first* (``--claim-batch 3`` items per store round-trip, to
amortize slow-link latency), and they heartbeat their claim + work-item
leases — ``claim_timeout_s`` can be minutes for real cloud deployments
while a worker that dies silently is reaped within seconds of its
``lease_s`` horizon.

    PYTHONPATH=src python examples/shared_store_workers.py
"""

import os
import subprocess
import sys
import tempfile
import time

import numpy as np

MEASURE_LATENCY_S = 0.005


def build_ds(store_path):
    """Worker factory (``--factory shared_store_workers:build_ds``): every
    process rebuilds the same (Ω, A) => same space_id => one shared study."""
    from repro.core import (ActionSpace, Dimension, DiscoverySpace,
                            FunctionExperiment, ProbabilitySpace, SampleStore)

    space = ProbabilitySpace.make([
        Dimension.categorical("instance", ["m5.large", "m5.xlarge", "c5.xlarge"]),
        Dimension.discrete("workers", [1, 2, 4, 8]),
        Dimension.discrete("batch_size", [16, 32, 64]),
    ])
    exp = FunctionExperiment(fn=deploy_and_measure, properties=("tokens_per_s",),
                             name="cloud-deploy")
    # claim_timeout_s is the slow-experiment horizon; lease_s is the fast
    # death-detection horizon the workers heartbeat against
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                          store=SampleStore(store_path), claim_timeout_s=30.0,
                          lease_s=5.0)


def deploy_and_measure(c):
    time.sleep(MEASURE_LATENCY_S)  # pretend this deploys to a cloud
    rate = {"m5.large": 90.0, "m5.xlarge": 170.0, "c5.xlarge": 210.0}[c["instance"]]
    eff = min(1.0, 0.4 + 0.15 * np.log2(c["workers"] * c["batch_size"] / 16))
    return {"tokens_per_s": rate * c["workers"] * eff}


def start_worker(store_path: str, tag: str) -> subprocess.Popen:
    """Launch ``python -m repro.core.execution.worker`` against the shared
    store — on a real deployment this line runs on another machine."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [src, here, os.environ.get("PYTHONPATH", "")]))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.execution.worker",
         "--store", store_path,
         "--factory", "shared_store_workers:build_ds",
         "--idle-timeout", "3", "--claim-batch", "3", "--owner", tag],
        env=env, stdout=subprocess.PIPE, text=True)


def main() -> None:
    from repro.core.optimizers import RandomSearch, run_optimizer

    with tempfile.TemporaryDirectory() as d:
        store_path = os.path.join(d, "common_context.db")
        ds = build_ds(store_path)  # also creates the schema up front

        print("Starting two measurement workers against the shared store:")
        workers = [start_worker(store_path, tag)
                   for tag in ("worker-A", "worker-B")]

        # The investigator: pipelined ask/tell, execution via the store's
        # work-item queue.  This process never runs an experiment itself.
        run = run_optimizer(RandomSearch(seed=0), ds, "tokens_per_s", "max",
                            max_trials=24, patience=25,
                            rng=np.random.default_rng(0),
                            max_inflight=6, backend="queue")
        print(f"  [investigator] pid={os.getpid()} trials={run.num_trials} "
              f"measured={run.num_measured} reused={run.num_reused} "
              f"best={run.best.value:.1f} tokens/s")

        processed = 0
        for proc, tag in zip(workers, ("worker-A", "worker-B")):
            out, _ = proc.communicate(timeout=60)
            assert proc.returncode == 0, (tag, proc.returncode)
            print(f"  [{tag}] {out.strip()}")
            processed += int(out.split("processed")[1].split()[0])

        samples = ds.read()
        measured = ds.store.count_measured(ds.space_id)
        print(f"\nReconciled: {len(samples)} distinct configurations, "
              f"{measured} measurements total, "
              f"{processed} work items executed by the workers")
        assert measured == len(samples) <= 36
        assert processed == run.num_trials
        assert ds.store.pending_work(ds.space_id) == 0
        print("  => every configuration was measured exactly once, and every "
              "measurement ran in a worker process")

        records = ds.timeseries(run.operation_id)
        seqs = [r.seq for r in records]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        print("  => the sampling record is gapless despite remote execution")

        best = max(samples, key=lambda s: s.value("tokens_per_s"))
        print(f"  best: {dict(best.configuration.values)} "
              f"-> {best.value('tokens_per_s'):.1f} tokens/s")


if __name__ == "__main__":
    main()
