"""Two best-of-breed optimizers cooperating over one shared store (paper §V).

The paper's headline sharing claim, demonstrated end to end: a TPE and a
GP-BO optimizer search the SAME cloud-configuration Discovery Space as one
:class:`~repro.core.campaign.Campaign`.  Each keeps its own operation, rng,
and stopping rule, but before every ask it folds the other's completed
measurements into its history (``SearchAdapter.sync_foreign`` — an
incremental, watermark-paged read of the shared sampling record), so both
models train on the union of the fleet's data and neither ever re-pays for
a configuration the other measured:

* foreign tells are visible in each member's history size (own + foreign);
* overlapping proposals land as transparent ``reused`` trials — the store's
  measurement-claim arbitration guarantees measure-once across the fleet;
* a shared-vs-isolated comparison on the same seeds shows the cooperative
  fleet reaching the best configuration in no more paid measurements
  (the full seed-set version is ``python -m benchmarks.campaign_bench``,
  writing BENCH_sharing.json).

    PYTHONPATH=src python examples/cooperative_campaign.py [--quick]
"""

import argparse
import time

import numpy as np

from repro.core import (ActionSpace, Campaign, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.optimizers import GPBayesOpt, TPE


def build_ds(store=None):
    space = ProbabilitySpace.make([
        Dimension.categorical("instance", ["m5.large", "m5.xlarge",
                                           "c5.xlarge", "c5.2xlarge"]),
        Dimension.discrete("workers", [1, 2, 4, 8]),
        Dimension.discrete("batch_size", [8, 16, 32, 64]),
        Dimension.discrete("prefetch", [1, 2, 4]),
    ])
    exp = FunctionExperiment(fn=deploy_and_measure,
                             properties=("cost_per_1k",), name="cloud-deploy")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


def deploy_and_measure(c):
    rate = {"m5.large": 90.0, "m5.xlarge": 170.0,
            "c5.xlarge": 210.0, "c5.2xlarge": 400.0}[c["instance"]]
    price = {"m5.large": 0.096, "m5.xlarge": 0.192,
             "c5.xlarge": 0.17, "c5.2xlarge": 0.34}[c["instance"]]
    eff = min(1.0, 0.4 + 0.13 * np.log2(c["workers"] * c["batch_size"] / 8))
    eff *= 1.0 + 0.05 * np.log2(c["prefetch"])
    throughput = rate * c["workers"] * eff
    return {"cost_per_1k": 1000.0 * price * c["workers"] / (3.6 * throughput)}


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="smaller budgets (CI smoke mode)")
    args = parser.parse_args(argv)
    per_member = 8 if args.quick else 16

    t0 = time.perf_counter()
    ds = build_ds()
    campaign = Campaign(
        ds, [TPE(seed=0), GPBayesOpt(seed=1)], "cost_per_1k", mode="min",
        max_trials=per_member, patience=per_member + 1, backend="serial",
        rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    res = campaign.run()

    print(f"Cooperative campaign over one shared store "
          f"({time.perf_counter() - t0:.1f}s):")
    for m in res.members:
        best = (f"best={m.best.value:.3f} $/1k tokens" if m.best
                else "(no deployable best)")
        print(f"  [{m.optimizer:5s}] op={m.operation_id[:24]} "
              f"own trials={m.run.num_trials} (measured={m.run.num_measured}) "
              f"+ foreign tells={m.foreign_trials} "
              f"=> model trained on {m.history_size} samples; {best}")
    best = res.best
    print(f"  fleet: {res.num_trials} trials, {res.num_measured} paid "
          f"measurements, best {best.value:.3f} $/1k at "
          f"{dict(best.configuration.values)}")

    # every member trained on more data than it paid for — the §V claim
    for m in res.members:
        assert m.history_size > m.run.num_trials, "no sharing happened?"
        assert m.foreign_trials > 0
    # measure-once across the fleet: paid measurements == distinct configs
    distinct = {t.configuration.digest for _, t in res.events}
    assert ds.store.count_measured(ds.space_id) == len(distinct)
    print("  => every member's model trained on the union of the fleet's "
          "history, and no configuration was ever measured twice")


if __name__ == "__main__":
    main()
