"""Record the bundled sample actuation trace (examples/traces/).

A synthetic cloud connector — phased provision / run / parse / teardown
with realistic warts: per-instance startup times and hourly rates, a
capacity-starved zone that flakes provisioning (retried by the lifecycle),
one permanently-out-of-capacity corner, and an OOM corner that fails at the
run phase.  Everything runs on a ``FakeClock``, so recording the 50-trial
trace takes milliseconds of wall-clock while the trace itself spans hours
of virtual provisioned time — and replaying it is deterministic down to the
billed cent.

Regenerate with::

    PYTHONPATH=src python examples/record_actuation_trace.py

Replay it through a full investigation with::

    PYTHONPATH=src python -m repro.core.api run examples/specs/trace_replay.json
"""

import os

import numpy as np

from repro.core import Dimension, ProbabilitySpace
from repro.core.actions import MeasurementError, ProvisioningError
from repro.core.clock import FakeClock
from repro.core.connector import (Deployment, DimensionPricing,
                                  ExperimentConnector, LifecycleExperiment,
                                  RetryPolicy, record_trace)

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "traces", "sample_actuation.jsonl")

#: $/hour on-demand prices, converted to $/s by the pricing model below.
HOURLY = {"m5.large": 0.096, "m5.xlarge": 0.192,
          "c5.xlarge": 0.17, "c5.2xlarge": 0.34}
STARTUP_S = {"m5.large": 35.0, "m5.xlarge": 40.0,
             "c5.xlarge": 45.0, "c5.2xlarge": 55.0}
BASE_RATE = {"m5.large": 210.0, "m5.xlarge": 420.0,
             "c5.xlarge": 520.0, "c5.2xlarge": 990.0}


def space():
    return ProbabilitySpace.make([
        Dimension.categorical("instance", list(HOURLY)),
        Dimension.discrete("workers", [1, 2, 4, 8]),
        Dimension.discrete("batch_size", [8, 16, 32, 64]),
    ])


class SyntheticCloud(ExperimentConnector):
    """A simulated provider: deterministic performance surface, flaky
    capacity.  ``c5.xlarge`` needs one extra provisioning attempt (the
    capacity-starved zone); ``c5.2xlarge`` at 8 workers never provisions;
    ``m5.large`` at batch 64 OOMs during the benchmark run."""

    name = "synthetic-cloud"
    version = "1"

    def __init__(self, clock):
        self.clock = clock
        self._attempts = {}

    @property
    def parameterization(self):
        return {"region": "sim-east-1"}

    @property
    def observed_properties(self):
        return ("throughput", "startup_s")

    def provision(self, configuration):
        inst = configuration["instance"]
        d = configuration.digest
        n = self._attempts[d] = self._attempts.get(d, 0) + 1
        if inst == "c5.2xlarge" and configuration["workers"] == 8:
            self.clock.sleep(12.0)  # the API rejects the request quickly
            raise ProvisioningError("InsufficientInstanceCapacity")
        if inst == "c5.xlarge" and n == 1:
            self.clock.sleep(18.0)
            raise ProvisioningError("capacity rebalancing, try again")
        self.clock.sleep(STARTUP_S[inst] * configuration["workers"] ** 0.5)
        return Deployment(ident=f"fleet-{d[:10]}",
                          configuration=configuration, handle=d,
                          meta={"startup_s": self.clock.time()})

    def run(self, deployment):
        c = deployment.configuration
        if c["instance"] == "m5.large" and c["batch_size"] == 64:
            self.clock.sleep(30.0)
            raise MeasurementError("worker OOM-killed at batch 64")
        # scaling is sublinear in workers, batch helps with a knee at 32
        rate = (BASE_RATE[c["instance"]] * c["workers"] ** 0.8
                * min(c["batch_size"], 32) / 32.0)
        self.clock.sleep(120.0)  # the benchmark itself
        return {"throughput": round(rate, 3),
                "startup_s": STARTUP_S[c["instance"]] * c["workers"] ** 0.5}

    def teardown(self, deployment):
        self.clock.sleep(3.0)


def main():
    clock = FakeClock()
    experiment = LifecycleExperiment(
        SyntheticCloud(clock),
        retry=RetryPolicy(provision_attempts=3, backoff_s=5.0,
                          backoff_factor=2.0, jitter=0.1),
        pricing=DimensionPricing(
            dimension="instance",
            rates=tuple(sorted((k, v / 3600.0) for k, v in HOURLY.items())),
            default=0.0001),
        clock=clock)
    rng = np.random.default_rng(0)
    configs = space().sample_configurations(rng, 50)
    t0 = clock.time()
    header, trials = record_trace(experiment, configs, path=OUT, clock=clock)
    ok = sum(1 for t in trials if t["properties"] is not None)
    print(f"recorded {len(trials)} trials ({ok} ok, {len(trials) - ok} "
          f"failed) spanning {(clock.time() - t0) / 3600.0:.2f} virtual "
          f"hours -> {OUT}")


if __name__ == "__main__":
    main()
