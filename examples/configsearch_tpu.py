import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Deployment-configuration search on the production mesh — the paper's
# technique as a first-class framework feature (§Perf driver).
#
#   PYTHONPATH=src python examples/configsearch_tpu.py \
#       --arch granite-moe-3b-a800m --shape train_4k --trials 14
#
# Samples persist in experiments/tuning_store.db: rerunning (any optimizer)
# transparently reuses earlier compilations (paper Fig. 7 behaviour), and
# `--transfer-from <arch>` seeds a new architecture's search via RSSC.

import argparse
import json

from repro.launch.mesh import make_production_mesh
from repro.tuning.hillclimb import hillclimb_cell, transfer_tuning

STORE = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "tuning_store.db")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "hillclimb")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--trials", type=int, default=14)
    ap.add_argument("--optimizer", default="tpe",
                    choices=["tpe", "bo-gp", "bohb", "random"])
    ap.add_argument("--metric", default="step_time_s")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--transfer-from", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    if args.transfer_from:
        res = transfer_tuning(args.transfer_from, args.arch, args.shape, mesh,
                              store_path=STORE)
        print(json.dumps(res.summary(), indent=1))
        return

    result = hillclimb_cell(args.arch, args.shape, mesh,
                            optimizer=args.optimizer, trials=args.trials,
                            metric=args.metric, store_path=STORE,
                            seed=args.seed)
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR,
                       f"{args.arch}__{args.shape}__{args.optimizer}.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[configsearch] log saved to {out}")


if __name__ == "__main__":
    main()
