"""RSSC knowledge transfer between two real measured spaces.

Source: wall-clock training-step times of the reduced xlstm-125m over a
deployment space (batch × seq × attention chunk × remat), exhaustively
measured on this machine.  Target: the SAME space for the reduced
deepseek-67b (dense transformer) — a different architecture, i.e. a change
in the Action space (paper §IV-1's FT-TRANS pattern).

RSSC clusters the source samples, measures only the representatives in the
target, applies the r>0.7 / p<0.01 criteria, and (if they pass) installs the
linear surrogate as a predictor experiment — then sweeps predictions over
the rest of the target space.

    PYTHONPATH=src python examples/rssc_transfer.py
"""

import numpy as np

from repro.core import (ActionSpace, Dimension, DiscoverySpace,
                        ProbabilitySpace, SampleStore, prediction_quality,
                        rssc_transfer)
from repro.tuning.experiments import WalltimeExperiment


def main():
    space = ProbabilitySpace.make([
        Dimension.discrete("batch", [1, 2, 4]),
        Dimension.discrete("seq", [32, 64, 128]),
        Dimension.discrete("attn_q_chunk", [16, 32, 64]),
        Dimension.categorical("remat", ["none", "full"]),
    ])
    store = SampleStore(":memory:")
    ds_src = DiscoverySpace(
        space=space,
        actions=ActionSpace.make([WalltimeExperiment("xlstm-125m", repeats=2)]),
        store=store)
    ds_tgt = DiscoverySpace(
        space=space,
        actions=ActionSpace.make([WalltimeExperiment("deepseek-67b", repeats=2)]),
        store=store)

    print(f"exhaustively characterizing the source ({space.size} configs, "
          f"measured wall-times — takes a minute)...")
    for c in list(ds_src.remaining_configurations()):
        s = ds_src.sample(c)
    src_best = min(ds_src.read(), key=lambda s: s.value("step_ms"))
    print(f"source best: {src_best.configuration.as_dict()} "
          f"{src_best.value('step_ms'):.1f} ms\n")

    res = rssc_transfer(ds_src, ds_tgt, "step_ms", mapping=None,
                        rng=np.random.default_rng(0))
    print(f"representative sub-space: {len(res.representatives)} points")
    print(f"transfer criteria: r={res.assessment.r:+.3f} "
          f"p={res.assessment.p_value:.2g} -> "
          f"{'TRANSFER' if res.transferable else 'NO TRANSFER'}")
    if not res.transferable:
        return

    preds = res.predicted_space.read()
    n_pred = sum(1 for s in preds if s.properties["step_ms"].predicted)
    print(f"predicted {n_pred} of {len(preds)} target configs from "
          f"{res.n_target_measured} real measurements "
          f"({100 * (1 - res.n_target_measured / space.size):.0f}% of "
          f"target sampling cost saved)\n")

    # score against ground truth (exhaustive target, for evaluation only)
    truth_ds = DiscoverySpace(space=space, actions=ds_tgt.actions, store=store)
    pred_vals, true_vals = [], []
    for s in preds:
        pred_vals.append(s.value("step_ms"))
        true_vals.append(truth_ds.sample(s.configuration).value("step_ms"))
    q = prediction_quality(np.array(pred_vals), np.array(true_vals),
                           n_measured=res.n_target_measured, mode="min")
    print("prediction quality vs ground truth:", q.summary())


if __name__ == "__main__":
    main()
