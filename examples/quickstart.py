"""Quickstart: the Discovery Space abstraction in five minutes.

Defines the paper's §III-B2 example — a ``gpu_flops`` experiment over
{gpu_model} × {batch_size} — then shows the TRACE behaviours: transparent
reuse, time-resolved records, reconciliation between two spaces sharing one
common context, and an optimizer run.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.optimizers import GPBayesOpt, run_optimizer

MEASUREMENTS = {"count": 0}


def gpu_flops(config):
    """Pretend to deploy and benchmark a GPU (the paper's example)."""
    MEASUREMENTS["count"] += 1
    peak = {"A100": 312.0, "V100": 125.0, "T4": 65.0}[config["gpu_model"]]
    eff = min(1.0, 0.35 + 0.18 * np.log2(config["batch_size"]))
    return {"tflops": peak * eff}


def main():
    # D = (P, Ω) ⊗ A
    space = ProbabilitySpace.make([
        Dimension.categorical("gpu_model", ["A100", "V100", "T4"]),
        Dimension.discrete("batch_size", [2, 4, 8, 16]),
    ])
    actions = ActionSpace.make([FunctionExperiment(
        fn=gpu_flops, properties=("tflops",), name="gpu_flops")])
    store = SampleStore(":memory:")  # the common context
    ds = DiscoverySpace(space=space, actions=actions, store=store)
    print(f"Discovery Space: |Ω| = {ds.space.size} configurations\n")

    # --- sample a point; sampling again REUSES (never re-measures)
    c = Configuration.make({"gpu_model": "A100", "batch_size": 8})
    s1 = ds.sample(c)
    s2 = ds.sample(c)
    print(f"A100@8 -> {s1.value('tflops'):.1f} TFLOP/s "
          f"(measured once, {MEASUREMENTS['count']} total measurements)")
    print("time-resolved record:",
          [(r.seq, r.action) for r in ds.timeseries()], "\n")

    # --- a second study over the same store: sees nothing until it samples,
    #     then reconciles from the common context without re-measuring
    ds_b = DiscoverySpace(space=space, actions=actions, store=store,
                          space_id="colleagues-study")
    print("colleague's study reads:", len(ds_b.read()), "samples (isolated)")
    ds_b.sample(c)
    print("after sampling the same config:", len(ds_b.read()), "sample,",
          MEASUREMENTS["count"], "total measurements (reused!)\n")

    # --- optimize: find max TFLOP/s
    run = run_optimizer(GPBayesOpt(seed=0), ds, "tflops", "max",
                        max_trials=8, patience=4)
    best = run.best
    print(f"BO found {best.configuration.as_dict()} -> "
          f"{best.value:.1f} TFLOP/s in {run.num_trials} trials "
          f"({run.num_reused} reused from the store)")
    print("remaining unsampled configurations:",
          len(list(ds.remaining_configurations())))


if __name__ == "__main__":
    main()
